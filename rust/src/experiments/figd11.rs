//! Figure D.11 — latency / throughput / peak memory vs model size:
//! measured across the CPU bench shapes plus the analytic paper-scale
//! ledger (125M .. 6.7B, fp16).

use crate::benchkit::{fmt_bytes, fmt_time, Table};
use crate::cli::Args;
use crate::engine::conv_cache::ConvCacheEngine;
use crate::engine::memory::{self};
use crate::engine::recurrent::RecurrentEngine;
use crate::engine::transformer::TransformerEngine;
use crate::engine::{run_generation, Engine, LmShape};
use crate::util::Prng;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let batch = args.get_usize("batch", 2);
    let t = args.get_usize("prompt", 64);
    let k = args.get_usize("tokens", 16);
    let mut rng = Prng::new(7);
    let mut table = Table::new(&[
        "shape", "params", "engine", "latency/tok", "tok/s", "peak state",
    ]);
    for name in ["nano", "micro"] {
        let shape = LmShape::bench(name).unwrap();
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|_| (0..t).map(|_| rng.below(shape.vocab) as i32).collect())
            .collect();
        for which in ["transformer", "hyena-conv", "laughing-hyena"] {
            let mut eng: Box<dyn Engine> = match which {
                "transformer" => Box::new(TransformerEngine::new(&shape, batch, 7)),
                "hyena-conv" => Box::new(ConvCacheEngine::new(&shape, batch, 7)),
                _ => Box::new(RecurrentEngine::new(&shape, batch, 7)),
            };
            let r = run_generation(eng.as_mut(), &prompts, k);
            table.row(&[
                name.into(),
                format!("{:.1}M", shape.params() as f64 / 1e6),
                which.into(),
                fmt_time(r.decode_s / (k - 1) as f64),
                format!("{:.1}", (batch * (k - 1)) as f64 / r.decode_s),
                fmt_bytes(r.peak_state_bytes),
            ]);
        }
    }
    table.print(&format!("Figure D.11 (measured, batch {batch}, T={t}, K={k})"));
    table.write_csv("figD11_measured.csv")?;

    // analytic paper-scale scaling (fp16, batch 64, T=512, K=256)
    let mut paper = Table::new(&[
        "size", "kv cache/seq", "ssm state/seq", "ratio", "max batch tr", "max batch lh",
    ]);
    for size in ["125m", "355m", "1.3b", "2.7b", "6.7b"] {
        let s = LmShape::paper(size).unwrap();
        let kv = memory::kv_cache_bytes(&s, 768, 2);
        let ssm = memory::ssm_state_bytes(&s, 2);
        let w = memory::weight_bytes(&s, 2);
        let budget = 80u64 << 30;
        paper.row(&[
            size.into(),
            fmt_bytes(kv),
            fmt_bytes(ssm),
            format!("{:.0}x", kv as f64 / ssm as f64),
            memory::max_batch(kv, w, budget).to_string(),
            memory::max_batch(ssm, w, budget).to_string(),
        ]);
    }
    paper.print("Figure D.11 (paper-scale state ledger, fp16, T+K=768)");
    paper.write_csv("figD11_paper.csv")?;
    Ok(())
}
