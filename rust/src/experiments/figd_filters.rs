//! Figures D.6–D.8 — long-conv filters at initialization vs after training:
//! trained filters decay and become low-dimensional; init filters are
//! rough/high-dimensional (the App. E.2 observation that motivates
//! post-training distillation).

use crate::benchkit::Table;
use crate::cli::Args;
use crate::hankel::effective_dimension;
use crate::runtime::artifact::{Runtime, Value};
use crate::runtime::checkpoint::Checkpoint;

pub fn run(_args: &Args) -> anyhow::Result<()> {
    let dir = super::common::require_artifacts()?;
    let tag = "multihyena_small";
    let rt = Runtime::cpu()?;
    let to_values = |ck: &Checkpoint| -> Vec<Value> {
        ck.tensors.iter().map(|t| Value::f32(t.data.clone(), &t.shape)).collect()
    };
    let init_ck = Checkpoint::load(&dir.join(format!("params_{tag}")))?;
    let init_f = super::common::extract_filters(&rt, &dir, tag, &to_values(&init_ck))?;
    let trained_base = std::path::Path::new("results/trained_multihyena_small");
    let trained_f = if trained_base.with_extension("bin").exists() {
        let ck = Checkpoint::load(trained_base)?;
        Some(super::common::extract_filters(&rt, &dir, tag, &to_values(&ck))?)
    } else {
        println!("note: run tab5.1 first to compare trained filters");
        None
    };

    let mut table = Table::new(&[
        "layer", "head", "init |h| head/tail", "init eff-dim", "trained eff-dim",
    ]);
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("layer,head,phase,t,h\n");
    for (li, layer) in init_f.iter().enumerate() {
        for (hi, taps) in layer.iter().enumerate().take(3) {
            let head: f64 = taps[..16].iter().map(|x| x.abs()).sum();
            let tail: f64 = taps[taps.len() - 16..].iter().map(|x| x.abs()).sum();
            let e_init = effective_dimension(&taps[1..], 1e-3);
            let e_train = trained_f
                .as_ref()
                .map(|f| effective_dimension(&f[li][hi][1..], 1e-3).to_string())
                .unwrap_or_else(|| "-".into());
            table.row(&[
                li.to_string(),
                hi.to_string(),
                format!("{:.2}/{:.3}", head, tail),
                e_init.to_string(),
                e_train,
            ]);
            for (t, h) in taps.iter().enumerate().step_by(4) {
                csv.push_str(&format!("{li},{hi},init,{t},{h:.6}\n"));
            }
            if let Some(f) = &trained_f {
                for (t, h) in f[li][hi].iter().enumerate().step_by(4) {
                    csv.push_str(&format!("{li},{hi},trained,{t},{h:.6}\n"));
                }
            }
        }
    }
    std::fs::write("results/figD_filters.csv", csv)?;
    table.print("Figures D.6-D.8: filters at init vs trained (taps in results/figD_filters.csv)");
    Ok(())
}
