//! Figure 5.4 — peak generation memory vs number of generated tokens:
//! recurrent models are flat; caches grow linearly in K.

use crate::benchkit::{fmt_bytes, Table};
use crate::cli::Args;
use crate::engine::conv_cache::ConvCacheEngine;
use crate::engine::memory::{self, F32};
use crate::engine::recurrent::RecurrentEngine;
use crate::engine::transformer::TransformerEngine;
use crate::engine::{run_generation, Engine, LmShape};
use crate::util::Prng;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let shape = LmShape::bench(args.get("shape").unwrap_or("nano")).expect("shape");
    let batch = args.get_usize("batch", 4);
    let t = args.get_usize("prompt", 32);
    let mut rng = Prng::new(5);
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|_| (0..t).map(|_| rng.below(shape.vocab) as i32).collect())
        .collect();
    let mut table = Table::new(&["K", "transformer", "hyena-conv", "laughing-hyena"]);
    for k in [16usize, 32, 64, 128] {
        let mut cells = vec![k.to_string()];
        for which in ["transformer", "hyena-conv", "laughing-hyena"] {
            let mut eng: Box<dyn Engine> = match which {
                "transformer" => Box::new(TransformerEngine::new(&shape, batch, 7)),
                "hyena-conv" => Box::new(ConvCacheEngine::new(&shape, batch, 7)),
                _ => Box::new(RecurrentEngine::new(&shape, batch, 7)),
            };
            let r = run_generation(eng.as_mut(), &prompts, k);
            cells.push(fmt_bytes(r.peak_state_bytes));
        }
        table.row(&cells);
    }
    table.print(&format!(
        "Figure 5.4 (measured, shape {}, batch {batch}, T={t}): peak generation state",
        shape.name
    ));
    table.write_csv("fig5_4.csv")?;

    // paper-scale analytic version (1.3B, fp16, batch 64, T=512)
    let s = LmShape::paper("1.3b").unwrap();
    let b = 64u64;
    let mut analytic = Table::new(&["K", "transformer", "hyena-conv", "laughing-hyena"]);
    for k in [128usize, 256, 512, 1024] {
        analytic.row(&[
            k.to_string(),
            fmt_bytes(b * memory::kv_cache_bytes(&s, 512 + k, 2)),
            fmt_bytes(b * memory::conv_cache_bytes(&s, 512 + k, 2)),
            fmt_bytes(b * memory::ssm_state_bytes(&s, 2)),
        ]);
    }
    let _ = F32;
    analytic.print("Figure 5.4 (paper scale 1.3B fp16, batch 64, T=512): analytic ledger");
    analytic.write_csv("fig5_4_paper.csv")?;
    println!("paper shape: recurrent memory constant in K; ~3x gap at K=512");
    Ok(())
}
