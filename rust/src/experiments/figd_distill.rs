//! Figures D.1–D.5 — distillation error (min/mean/max over channels) vs
//! order, per model family: H3 IIR & FIR distill with tiny d; Hyena and
//! MultiHyena need larger orders (synthetic filter suites per DESIGN.md §6).

use crate::benchkit::Table;
use crate::cli::Args;
use crate::data::filters::{model_filters, Family};
use crate::distill::{DistillConfig, Distillery};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let n_filters = args.get_usize("filters", 6);
    let len = args.get_usize("len", 256);
    let iters = args.get_usize("iters", 1200);
    let orders = [2usize, 4, 8, 16, 32];
    let mut table =
        Table::new(&["family", "order", "min rel err", "mean rel err", "max rel err"]);
    let mut knee = Table::new(&["family", "order for mean err < 0.05"]);
    for fam in [Family::H3Iir, Family::H3Fir, Family::Hyena, Family::MultiHyena] {
        let filters = model_filters(fam, n_filters, len, 0xD0 + fam as u64);
        let mut first_good: Option<usize> = None;
        for &d in &orders {
            let distillery = Distillery {
                order: Some(d),
                fit: DistillConfig { iters, ..Default::default() },
                hankel_window: Some(64),
                ..Default::default()
            };
            let r = distillery.distill_all(&filters);
            if first_good.is_none() && r.mean_err() < 0.05 {
                first_good = Some(d);
            }
            table.row(&[
                fam.label().into(),
                d.to_string(),
                format!("{:.2e}", r.min_err()),
                format!("{:.2e}", r.mean_err()),
                format!("{:.2e}", r.max_err()),
            ]);
        }
        knee.row(&[
            fam.label().into(),
            first_good.map_or(">32".into(), |d| d.to_string()),
        ]);
        println!("  {} done", fam.label());
    }
    table.print("Figures D.1-D.5: distillation error vs order per family");
    table.write_csv("figD_distill_errors.csv")?;
    knee.print("Order needed per family (paper: H3 < 8, Hyena-family < 32)");
    knee.write_csv("figD_knee.csv")?;
    Ok(())
}
