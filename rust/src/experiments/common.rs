//! Shared experiment plumbing: artifact discovery, filter extraction and
//! distillation of a served model's trained filters.

use anyhow::{bail, Result};
use std::path::PathBuf;

use crate::distill::{DistillConfig, Objective};
use crate::dsp::C64;
use crate::runtime::artifact::{Runtime, Value};
use crate::ssm::ModalSsm;

/// Locate the artifacts directory (repo-root relative).
pub fn artifacts_dir() -> PathBuf {
    let cand = PathBuf::from("artifacts");
    if cand.exists() {
        return cand;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn require_artifacts() -> Result<PathBuf> {
    let dir = artifacts_dir();
    if !dir.join("STAMP").exists() {
        bail!("artifacts missing — run `make artifacts` first");
    }
    Ok(dir)
}

/// Materialize the trained long-conv filter taps of a checkpoint through
/// the `filters_<tag>` artifact.  Returns taps[layer][head] = full filter
/// [h0, h1, ...].
pub fn extract_filters(
    rt: &Runtime,
    dir: &std::path::Path,
    tag: &str,
    params: &[Value],
) -> Result<Vec<Vec<Vec<f64>>>> {
    let art = rt.load(dir, &format!("filters_{tag}"))?;
    let out = art.execute(params)?;
    let spec = &art.manifest.outputs[0];
    let (nl, m, l) = (spec.shape[0], spec.shape[1], spec.shape[2]);
    let data = out[0].as_f32()?;
    let mut filters = vec![vec![vec![0.0f64; l]; m]; nl];
    for li in 0..nl {
        for hi in 0..m {
            for t in 0..l {
                filters[li][hi][t] = data[(li * m + hi) * l + t] as f64;
            }
        }
    }
    Ok(filters)
}

/// Distill every filter of a model to the given order, then zero-pad the
/// modal systems to `d_state` slots (zero residues are inert) so they fit
/// the fixed-shape decode artifact.
///
/// Every (layer, head) fit is independent and carries its own derived seed,
/// so the whole bank fans out over the persistent
/// [`crate::util::pool::Pool`] workers with results identical to the
/// sequential order (row-major over layers then heads).
pub fn distill_filters(
    filters: &[Vec<Vec<f64>>],
    order: usize,
    d_state: usize,
    iters: usize,
) -> (Vec<Vec<ModalSsm>>, Vec<f64>) {
    assert!(order <= d_state, "order {order} exceeds artifact d_state {d_state}");
    let jobs: Vec<(usize, usize, &Vec<f64>)> = filters
        .iter()
        .enumerate()
        .flat_map(|(li, layer)| {
            layer.iter().enumerate().map(move |(hi, taps)| (li, hi, taps))
        })
        .collect();
    let results = crate::util::pool::Pool::auto().map(jobs, |(li, hi, taps)| {
        let cfg = DistillConfig {
            order,
            iters,
            seed: (li * 131 + hi) as u64,
            objective: Objective::L2,
            restarts: 1,
            ..DistillConfig::default()
        };
        let r = crate::distill::modal_fit::distill_modal(&taps[1..], taps[0], &cfg);
        (r.rel_err, pad_modal(&r.ssm, d_state))
    });
    let mut rel_errs = Vec::with_capacity(results.len());
    let mut systems: Vec<Vec<ModalSsm>> = Vec::with_capacity(filters.len());
    let mut it = results.into_iter();
    for layer in filters {
        let mut row = Vec::with_capacity(layer.len());
        for _ in layer {
            let (err, sys) = it.next().expect("one result per filter");
            rel_errs.push(err);
            row.push(sys);
        }
        systems.push(row);
    }
    (systems, rel_errs)
}

/// Zero-pad a modal system with inert modes up to dimension d.
pub fn pad_modal(sys: &ModalSsm, d: usize) -> ModalSsm {
    let mut poles = sys.poles.clone();
    let mut residues = sys.residues.clone();
    while poles.len() < d {
        poles.push(C64::ZERO);
        residues.push(C64::ZERO);
    }
    ModalSsm::new(poles, residues, sys.h0)
}

/// Relative l1 error between two logit vectors (Figure 5.1's metric).
pub fn rel_l1(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum();
    let den: f64 = b.iter().map(|y| y.abs() as f64).sum();
    num / den.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_modal_is_inert() {
        let sys = ModalSsm::new(
            vec![C64::polar(0.8, 1.0)],
            vec![C64::new(0.5, -0.2)],
            0.3,
        );
        let padded = pad_modal(&sys, 4);
        assert_eq!(padded.order(), 4);
        let a = sys.impulse_response(16);
        let b = padded.impulse_response(16);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn rel_l1_basics() {
        assert_eq!(rel_l1(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rel_l1(&[1.1, 2.0], &[1.0, 2.0]) - 0.1 / 3.0).abs() < 1e-6);
    }
}
