//! Figures D.9–D.10 — distribution of Hankel singular values per model
//! family: H3 spectra collapse fast, Hyena slower, MultiHyena slowest
//! (larger effective dimension — the §4 motivation for weight tying).

use crate::benchkit::Table;
use crate::cli::Args;
use crate::data::filters::{model_filters, Family};
use crate::hankel::{effective_dimension, hankel_singular_values};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let n_filters = args.get_usize("filters", 8);
    let len = args.get_usize("len", 256);
    let mut table = Table::new(&[
        "family", "sigma5/s1", "sigma10/s1", "sigma20/s1", "sigma40/s1", "eff dim (1e-3)",
    ]);
    for fam in [Family::H3Iir, Family::Hyena, Family::MultiHyena] {
        let filters = model_filters(fam, n_filters, len, 0xD9 + fam as u64);
        let mut ratios = [0.0f64; 4];
        let mut eff = 0.0f64;
        for f in &filters {
            let sv = hankel_singular_values(&f[1..], Some(64));
            for (i, &idx) in [4usize, 9, 19, 39].iter().enumerate() {
                ratios[i] += sv.get(idx).copied().unwrap_or(0.0) / sv[0] / n_filters as f64;
            }
            eff += effective_dimension(&f[1..], 1e-3) as f64 / n_filters as f64;
        }
        table.row(&[
            fam.label().into(),
            format!("{:.2e}", ratios[0]),
            format!("{:.2e}", ratios[1]),
            format!("{:.2e}", ratios[2]),
            format!("{:.2e}", ratios[3]),
            format!("{eff:.1}"),
        ]);
    }
    table.print("Figures D.9-D.10: Hankel spectrum decay per family");
    table.write_csv("figD_hankel.csv")?;
    println!("paper shape: effective dimension H3 << Hyena <= MultiHyena");
    Ok(())
}
