//! Synthetic language corpus: a Zipf-weighted first-order Markov chain over
//! the vocabulary.  Learnable structure (bigram statistics + skip tokens)
//! without external data; perplexity orderings between architectures are
//! measured on held-out samples of the same process.

use crate::util::Prng;

/// Zipf-Markov corpus generator.
pub struct Corpus {
    vocab: usize,
    /// Per-state transition weights (vocab x branching sparse table).
    table: Vec<Vec<(usize, f64)>>,
    rng: Prng,
}

impl Corpus {
    /// Build a corpus process. `branching` successors per state, weights
    /// Zipf-distributed, plus a long-range "copy token" mechanic: token 0
    /// triggers re-emission of an earlier token, giving the sequence a
    /// recall-like long dependency that long-convolution models exploit.
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Corpus {
        let mut rng = Prng::new(seed);
        let mut table = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let mut succ = Vec::with_capacity(branching);
            for k in 0..branching {
                let tok = rng.below(vocab);
                let w = 1.0 / (k + 1) as f64; // Zipf over the branch rank
                succ.push((tok, w));
            }
            table.push(succ);
        }
        Corpus { vocab, table, rng }
    }

    /// Fresh sampler over the SAME process (held-out evaluation must see
    /// the same transition table, only different draws).
    pub fn fork(&self, seed: u64) -> Corpus {
        Corpus { vocab: self.vocab, table: self.table.clone(), rng: Prng::new(seed) }
    }

    /// Sample a sequence of length `len`.
    pub fn sample(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut state = self.rng.below(self.vocab);
        for t in 0..len {
            // copy mechanic: with small probability, re-emit token from 8 back
            if t >= 8 && self.rng.uniform() < 0.05 {
                state = out[t - 8] as usize;
            }
            out.push(state as i32);
            let succ = &self.table[state];
            let weights: Vec<f64> = succ.iter().map(|(_, w)| *w).collect();
            state = succ[self.rng.categorical(&weights)].0;
        }
        out
    }

    /// Sample a [batch, len] token matrix plus next-token targets.
    pub fn batch(&mut self, batch: usize, len: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * len);
        let mut targets = Vec::with_capacity(batch * len);
        for _ in 0..batch {
            let seq = self.sample(len + 1);
            tokens.extend(&seq[..len]);
            targets.extend(&seq[1..]);
        }
        (tokens, targets)
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(64, 4, 1);
        let seq = c.sample(500);
        assert_eq!(seq.len(), 500);
        assert!(seq.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn batch_targets_are_shifted() {
        let mut c = Corpus::new(32, 4, 2);
        let (tok, tgt) = c.batch(2, 16);
        assert_eq!(tok.len(), 32);
        assert_eq!(tgt.len(), 32);
        // within each row, target_t == token_{t+1}
        for row in 0..2 {
            for t in 0..15 {
                assert_eq!(tgt[row * 16 + t], tok[row * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn distribution_is_learnable_not_uniform() {
        // bigram structure: successors of a state concentrate on few tokens
        let mut c = Corpus::new(64, 4, 3);
        let seq = c.sample(5000);
        let mut succ_counts = vec![std::collections::BTreeMap::new(); 64];
        for w in seq.windows(2) {
            *succ_counts[w[0] as usize].entry(w[1]).or_insert(0usize) += 1;
        }
        // most states should have <= 8 distinct successors (4 branches +
        // copy-mechanic leakage), far below the uniform 64
        let small = succ_counts
            .iter()
            .filter(|m| !m.is_empty() && m.len() <= 12)
            .count();
        assert!(small > 40, "only {small} states have concentrated successors");
    }
}
