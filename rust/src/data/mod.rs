//! Data substrates (the paper trained on The Pile and benchmarked on
//! pre-trained checkpoints — neither is available offline, so these
//! generators produce the synthetic equivalents; DESIGN.md §6 documents why
//! each substitution preserves the relevant behaviour).

pub mod assoc_recall;
pub mod corpus;
pub mod filters;
