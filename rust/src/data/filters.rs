//! Synthetic "pre-trained" filter suites (DESIGN.md §6 substitution for the
//! paper's H3/Hyena/MultiHyena checkpoints, whose filters App. D
//! characterizes qualitatively):
//!
//! * H3-like diagonal ("IIR"): exact low-order modal systems — Hankel
//!   spectrum collapses after a handful of modes (Figure D.10: "decay
//!   rapidly"; §5.2: H3 distills with d < 8).
//! * H3-like shift ("FIR"): short explicit taps.
//! * Hyena-like implicit: many damped sinusoids under a decay envelope plus
//!   a small rough component — slow spectral decay (distills with d < 32).
//! * MultiHyena-like: even more modes per filter (Figure D.9: "larger
//!   effective dimension, slower decay") — weight tying packs more signal
//!   into each of the fewer filters.

use crate::dsp::C64;
use crate::ssm::ModalSsm;
use crate::util::Prng;

/// Filter family to synthesize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    H3Iir,
    H3Fir,
    Hyena,
    MultiHyena,
}

impl Family {
    pub fn label(&self) -> &'static str {
        match self {
            Family::H3Iir => "h3-iir",
            Family::H3Fir => "h3-fir",
            Family::Hyena => "hyena",
            Family::MultiHyena => "multihyena",
        }
    }
}

/// Generate one filter: full taps [h0, h1, ..., h_{len-1}].
pub fn filter(family: Family, len: usize, rng: &mut Prng) -> Vec<f64> {
    match family {
        Family::H3Iir => {
            let pairs = 2 + rng.below(2);
            modal_mixture(rng, pairs, 0.0, len)
        }
        Family::H3Fir => {
            // short explicit taps (kernel width ~4-8), zero beyond
            let k = 4 + rng.below(5);
            let mut taps = vec![0.0; len];
            for t in taps.iter_mut().take(k.min(len)) {
                *t = rng.normal() * 0.5;
            }
            taps
        }
        Family::Hyena => {
            let pairs = 8 + rng.below(5);
            modal_mixture(rng, pairs, 2e-4, len)
        }
        Family::MultiHyena => {
            let pairs = 14 + rng.below(7);
            modal_mixture(rng, pairs, 2e-4, len)
        }
    }
}

/// Mixture of damped complex sinusoids (conjugate-closed) with optional
/// rough noise floor — the decaying oscillatory shape App. D's filter
/// visualizations show for pre-trained models.
fn modal_mixture(rng: &mut Prng, pairs: usize, noise: f64, len: usize) -> Vec<f64> {
    let ps: Vec<(C64, C64)> = (0..pairs)
        .map(|k| {
            // timescales spread geometrically: slow modes dominate
            let r = 0.999 - 0.35 * (k as f64 + rng.uniform()) / pairs as f64;
            let th = rng.range(0.02, 2.8);
            let amp = rng.normal() * (1.0 / (1.0 + k as f64)).sqrt() * 0.4;
            (C64::polar(r.clamp(0.3, 0.999), th), C64::new(amp, rng.normal() * 0.1))
        })
        .collect();
    let sys = ModalSsm::from_conjugate_pairs(&ps, rng.normal() * 0.3);
    let mut taps = vec![sys.h0];
    taps.extend(sys.impulse_response(len - 1));
    if noise > 0.0 {
        for t in taps.iter_mut() {
            *t += noise * rng.normal();
        }
    }
    taps
}

/// A model's worth of filters: `count` filters of the family.
pub fn model_filters(family: Family, count: usize, len: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Prng::new(seed);
    (0..count).map(|_| filter(family, len, &mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hankel::hankel_singular_values;

    fn spectrum_knee(taps: &[f64], tol: f64) -> usize {
        let sv = hankel_singular_values(&taps[1..], Some(64));
        sv.iter().filter(|&&s| s > tol * sv[0]).count()
    }

    #[test]
    fn h3_filters_have_fast_hankel_decay() {
        let filters = model_filters(Family::H3Iir, 4, 128, 7);
        for f in &filters {
            let knee = spectrum_knee(f, 1e-4);
            assert!(knee <= 8, "H3-like filter should be <= 8 dim, got {knee}");
        }
    }

    #[test]
    fn hyena_filters_have_larger_effective_dimension() {
        // paper Figure D.9/D.10: Hyena >> H3 in effective dimension
        let h3: usize = model_filters(Family::H3Iir, 4, 128, 8)
            .iter()
            .map(|f| spectrum_knee(f, 1e-3))
            .sum();
        let hy: usize = model_filters(Family::Hyena, 4, 128, 8)
            .iter()
            .map(|f| spectrum_knee(f, 1e-3))
            .sum();
        let mh: usize = model_filters(Family::MultiHyena, 4, 128, 8)
            .iter()
            .map(|f| spectrum_knee(f, 1e-3))
            .sum();
        assert!(hy > h3, "hyena {hy} vs h3 {h3}");
        assert!(mh >= hy, "multihyena {mh} vs hyena {hy}");
    }

    #[test]
    fn fir_filters_are_short() {
        let filters = model_filters(Family::H3Fir, 3, 64, 9);
        for f in &filters {
            assert!(f[16..].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn filters_decay_to_zero() {
        for fam in [Family::H3Iir, Family::Hyena, Family::MultiHyena] {
            let f = &model_filters(fam, 1, 256, 10)[0];
            let head: f64 = f[..32].iter().map(|x| x.abs()).sum();
            let tail: f64 = f[224..].iter().map(|x| x.abs()).sum();
            assert!(tail < head, "{fam:?}: tail {tail} head {head}");
        }
    }
}
