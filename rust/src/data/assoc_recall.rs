//! Associative recall episodes (paper §4, Thm 4.1, Table E.1): sequences of
//! key-value pairs followed by a query key; the model must emit the value
//! associated with that key.

use crate::util::Prng;

/// One associative-recall episode, already laid out as a token sequence.
pub struct Episode {
    /// Token sequence: k1 v1 k2 v2 ... kq (padded to `len` with pad token).
    pub tokens: Vec<i32>,
    /// Target sequence (next-token), nonzero loss mask only at the answer.
    pub targets: Vec<i32>,
    /// Loss mask (1.0 exactly at the position predicting the answer).
    pub mask: Vec<f32>,
    /// The correct value token.
    pub answer: i32,
    /// Position whose *output* should be the answer (the query position).
    pub query_pos: usize,
}

/// Episode generator. Vocabulary layout: [0] pad, [1..=s] keys,
/// [s+1..=2s] values; requires vocab >= 2s+1.
pub struct AssocRecall {
    pub s: usize,
    pub len: usize,
    rng: Prng,
}

impl AssocRecall {
    pub fn new(s: usize, len: usize, seed: u64) -> AssocRecall {
        assert!(len >= 2 * s + 1, "sequence too short for {s} pairs");
        AssocRecall { s, len, rng: Prng::new(seed) }
    }

    /// Vocabulary needed by a model consuming these episodes.
    pub fn vocab(&self) -> usize {
        2 * self.s + 1
    }

    pub fn episode(&mut self) -> Episode {
        let s = self.s;
        // random bijection key -> value
        let mut vals: Vec<usize> = (0..s).collect();
        self.rng.shuffle(&mut vals);
        // random order of key presentation
        let mut order: Vec<usize> = (0..s).collect();
        self.rng.shuffle(&mut order);
        let mut tokens = Vec::with_capacity(self.len);
        for &k in &order {
            tokens.push((1 + k) as i32); // key token
            tokens.push((1 + s + vals[k]) as i32); // value token
        }
        let q = order[self.rng.below(s)];
        tokens.push((1 + q) as i32);
        let query_pos = tokens.len() - 1;
        let answer = (1 + s + vals[q]) as i32;
        tokens.resize(self.len, 0); // pad
        // next-token supervision at every key position (its target is the
        // paired value) plus the final query position (its target is the
        // answer) — dense recall signal, the form the task is learnable in
        // at small scale; value positions are unsupervised (their successor
        // key is random).
        let mut targets = vec![0i32; self.len];
        let mut mask = vec![0f32; self.len];
        for i in 0..s {
            targets[2 * i] = tokens[2 * i + 1];
            mask[2 * i] = 1.0;
        }
        targets[query_pos] = answer;
        mask[query_pos] = 1.0;
        Episode { tokens, targets, mask, answer, query_pos }
    }

    /// Batch of episodes flattened row-major.
    pub fn batch(&mut self, b: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<(usize, i32)>) {
        let mut tokens = Vec::with_capacity(b * self.len);
        let mut targets = Vec::with_capacity(b * self.len);
        let mut mask = Vec::with_capacity(b * self.len);
        let mut answers = Vec::with_capacity(b);
        for _ in 0..b {
            let e = self.episode();
            tokens.extend(&e.tokens);
            targets.extend(&e.targets);
            mask.extend(&e.mask);
            answers.push((e.query_pos, e.answer));
        }
        (tokens, targets, mask, answers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn episode_structure() {
        let mut g = AssocRecall::new(8, 32, 1);
        for _ in 0..20 {
            let e = g.episode();
            assert_eq!(e.tokens.len(), 32);
            assert_eq!(e.query_pos, 2 * 8);
            // query token appears earlier as a key
            let q = e.tokens[e.query_pos];
            let earlier: Vec<i32> = e.tokens[..e.query_pos].to_vec();
            let kpos = earlier.iter().position(|&t| t == q).expect("query key seen");
            assert_eq!(kpos % 2, 0, "keys at even positions");
            // answer is the value right after that key
            assert_eq!(e.tokens[kpos + 1], e.answer);
            // mask selects the query position + every key position
            assert_eq!(e.mask.iter().filter(|&&m| m > 0.0).count(), 8 + 1);
            assert_eq!(e.targets[e.query_pos], e.answer);
            for i in 0..8 {
                assert_eq!(e.targets[2 * i], e.tokens[2 * i + 1]);
            }
        }
    }

    #[test]
    fn values_and_keys_disjoint() {
        let mut g = AssocRecall::new(5, 16, 2);
        let e = g.episode();
        for (i, &t) in e.tokens[..11].iter().enumerate() {
            if i % 2 == 0 {
                assert!((1..=5).contains(&t), "key range");
            } else {
                assert!((6..=10).contains(&t), "value range");
            }
        }
    }

    #[test]
    fn batch_shapes() {
        let mut g = AssocRecall::new(4, 12, 3);
        let (tok, tgt, mask, ans) = g.batch(3);
        assert_eq!(tok.len(), 36);
        assert_eq!(tgt.len(), 36);
        assert_eq!(mask.len(), 36);
        assert_eq!(ans.len(), 3);
    }
}
