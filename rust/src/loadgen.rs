//! Deterministic load generator for the sharded serving front door.
//!
//! `repro loadgen` (and the `serve_load` chaos harness) drive a
//! [`crate::serve::FrontServer`] over real loopback TCP wire frames with a
//! workload whose *content* is a pure function of one seed: session
//! arrival order, per-session turn counts, think times, prompt lengths and
//! prompt tokens all come from per-stream splitmix64 generators — no
//! ambient entropy, so two runs with the same [`LoadConfig`] submit the
//! same prompts in the same per-session order.  (Wall-clock timing is of
//! course not deterministic; only the workload is.)
//!
//! Two driving modes:
//!
//! * **closed loop** (`rate_hz == 0`): every session starts immediately
//!   and each runs its turns back-to-back (with think-time pauses), so
//!   concurrency equals the live session count;
//! * **open loop** (`rate_hz > 0`): sessions *arrive* at the configured
//!   rate with exponentially distributed inter-arrival gaps, regardless
//!   of whether the cluster keeps up — the mode that actually exposes
//!   overload behavior, since arrivals do not slow down when the server
//!   does.
//!
//! Every turn is measured client-side into [`Hist`] latency histograms
//! (TTFT, mean TPOT, end-to-end) and every typed refusal
//! ([`ErrCode::Overloaded`], [`ErrCode::DeadlineExceeded`]) is counted
//! rather than treated as a failure: under deliberate overload a typed
//! shed is the *correct* answer.  [`bench_doc`] renders the report plus
//! the cluster's own counters (retries, TTL evictions, spill evictions,
//! sheds) into the checked-in `BENCH_load.json` shape.
//!
//! Every submitted turn also carries a deterministic nonzero trace id
//! (derived from the workload seed) with profiling on, so the front door
//! streams a [`Frame::Spans`] report back before `Done`.  The generator
//! folds each hop's total duration into per-hop histograms
//! ([`HOP_NAMES`]) and `bench_doc` emits them as the `client.hops`
//! percentile breakdown — the "where did the latency go" section.

use std::net::{SocketAddr, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use crate::benchkit::Json;
use crate::obs::hist::Hist;
use crate::obs::registry::{MetricValue, Snapshot};
use crate::obs::HopReport;
use crate::serve::wire::{self, ErrCode, Frame};

/// Hop names the per-hop latency breakdown tracks, in timeline order.
/// Indexes [`LoadReport::hop_totals`].
pub const HOP_NAMES: [&str; 5] = ["front", "router", "shard", "coordinator", "engine"];

/// Read timeout on loadgen client sockets: generous, because under
/// deliberate overload a queued turn legitimately waits a long time.
const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// Think-time samples are exponential with the configured mean but capped
/// at this multiple of it, so one unlucky draw cannot stall a bounded
/// test run.
const THINK_CAP: f64 = 4.0;

/// Workload shape for one loadgen run.  Everything the generator submits
/// derives from `seed` alone.
#[derive(Clone, Copy, Debug)]
pub struct LoadConfig {
    /// Total sessions driven over the run.
    pub sessions: usize,
    /// Mean turns per session (per-session counts are uniform on
    /// `1..=2*turns-1`, so the mean is exactly `turns`).
    pub turns: usize,
    /// Session arrival rate in sessions/second; `0.0` selects the closed
    /// loop (all sessions start at once).
    pub rate_hz: f64,
    /// Mean think time between a session's turns, in milliseconds
    /// (exponentially distributed, capped at [`THINK_CAP`]× the mean).
    pub think_ms: u64,
    /// Mean prompt (delta) length per turn, in tokens (uniform on
    /// `1..=2*prompt_len-1`).
    pub prompt_len: usize,
    /// Tokens requested per turn.
    pub max_new: usize,
    /// Deadline budget stamped on every submitted turn (0 = none; without
    /// a budget the front door refuses at capacity instead of queueing).
    pub deadline_ms: u32,
    /// Root of every workload stream.
    pub seed: u64,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            sessions: 32,
            turns: 3,
            rate_hz: 0.0,
            think_ms: 0,
            prompt_len: 8,
            max_new: 8,
            deadline_ms: 0,
            seed: 7,
        }
    }
}

/// One planned turn: the pause before it and the prompt delta it sends.
#[derive(Clone, Debug, PartialEq)]
pub struct TurnPlan {
    pub think: Duration,
    pub delta: Vec<i32>,
}

/// One planned session: its id, its arrival offset from the run start,
/// and its turns in order.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionPlan {
    pub sid: u64,
    pub start: Duration,
    pub turns: Vec<TurnPlan>,
}

/// splitmix64 step: the only entropy source in this module.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Independent stream for `(seed, key)`: one warm-up step decorrelates
/// streams whose keys differ by small deltas.
fn stream(seed: u64, key: u64) -> u64 {
    let mut s = seed ^ key.wrapping_mul(0x2545_f491_4f6c_dd1d);
    let _ = splitmix64(&mut s);
    s
}

/// Uniform in `[0, 1)` from one splitmix64 draw.
fn unit(state: &mut u64) -> f64 {
    (splitmix64(state) >> 11) as f64 / (1u64 << 53) as f64
}

/// Exponential sample with the given mean, capped at [`THINK_CAP`]× mean.
fn exp_capped(state: &mut u64, mean: f64) -> f64 {
    let u = unit(state);
    (-mean * (1.0 - u).ln()).min(THINK_CAP * mean)
}

/// Uniform integer on `1..=2*mean-1` (mean exactly `mean`); 0 stays 0.
fn around(state: &mut u64, mean: usize) -> usize {
    if mean == 0 {
        return 0;
    }
    1 + (splitmix64(state) % (2 * mean as u64 - 1)) as usize
}

/// Expand a [`LoadConfig`] into the full deterministic workload: every
/// session's arrival offset, turn count, think times and prompt deltas.
/// Pure — calling it twice yields identical plans.
pub fn plan(cfg: &LoadConfig) -> Vec<SessionPlan> {
    let mut arrivals = stream(cfg.seed, u64::MAX);
    let mut at = 0.0f64;
    (0..cfg.sessions)
        .map(|i| {
            let sid = i as u64;
            if cfg.rate_hz > 0.0 && i > 0 {
                at += exp_capped(&mut arrivals, 1.0 / cfg.rate_hz);
            }
            let mut rng = stream(cfg.seed, sid);
            let n_turns = around(&mut rng, cfg.turns);
            let turns = (0..n_turns)
                .map(|t| {
                    let think = if t > 0 && cfg.think_ms > 0 {
                        Duration::from_secs_f64(exp_capped(&mut rng, cfg.think_ms as f64) / 1e3)
                    } else {
                        Duration::ZERO
                    };
                    let len = around(&mut rng, cfg.prompt_len).max(1);
                    let delta: Vec<i32> =
                        (0..len).map(|_| 1 + (splitmix64(&mut rng) % 32) as i32).collect();
                    TurnPlan { think, delta }
                })
                .collect();
            SessionPlan { sid, start: Duration::from_secs_f64(at), turns }
        })
        .collect()
}

/// What one submitted turn came back as.
enum TurnOutcome {
    /// Completed generation: token count, client-side timings, and the
    /// cross-hop span report the front streamed back for our trace id.
    Done { toks: usize, ttft_s: f64, e2e_s: f64, hops: Vec<HopReport> },
    /// Typed refusal frame — the request was shed, session untouched.
    Refused(ErrCode),
    /// Connection-level failure (connect, framing, unexpected frame).
    Transport,
}

/// Aggregated result of a run (mergeable across session workers).
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    /// Turns that streamed to `Done`.
    pub turns_ok: u64,
    /// Tokens received across completed turns.
    pub tokens: u64,
    /// Typed [`ErrCode::Overloaded`] refusals (capacity / queue shed).
    pub refused_overloaded: u64,
    /// Typed [`ErrCode::DeadlineExceeded`] refusals.
    pub refused_deadline: u64,
    /// Any other typed error frame.
    pub refused_other: u64,
    /// Transport-level failures (no typed reply at all).
    pub transport_errors: u64,
    /// Client-observed submit → first token.
    pub ttft: Hist,
    /// Client-observed mean inter-token time after the first.
    pub tpot: Hist,
    /// Client-observed submit → final token.
    pub e2e: Hist,
    /// Per-hop total duration (seconds), indexed per [`HOP_NAMES`], from
    /// the span reports traced turns stream back.
    pub hop_totals: [Hist; 5],
    /// Wall time of the whole run, seconds.
    pub wall_s: f64,
}

impl LoadReport {
    /// Fold another worker's report into this one (hists merge exactly).
    pub fn absorb(&mut self, other: &LoadReport) {
        self.turns_ok += other.turns_ok;
        self.tokens += other.tokens;
        self.refused_overloaded += other.refused_overloaded;
        self.refused_deadline += other.refused_deadline;
        self.refused_other += other.refused_other;
        self.transport_errors += other.transport_errors;
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
        for (mine, theirs) in self.hop_totals.iter_mut().zip(&other.hop_totals) {
            mine.merge(theirs);
        }
    }

    /// Total turns submitted (completed + refused + failed).
    pub fn turns_submitted(&self) -> u64 {
        self.turns_ok
            + self.refused_overloaded
            + self.refused_deadline
            + self.refused_other
            + self.transport_errors
    }

    /// Human-readable multi-line summary.
    pub fn summary(&self) -> String {
        let q = |h: &Hist, p: f64| h.quantile(p) * 1e3;
        let mut s = String::new();
        s.push_str(&format!(
            "turns: {} ok, {} shed overloaded, {} shed deadline, {} other errors, \
             {} transport failures ({} submitted)\n",
            self.turns_ok,
            self.refused_overloaded,
            self.refused_deadline,
            self.refused_other,
            self.transport_errors,
            self.turns_submitted(),
        ));
        s.push_str(&format!(
            "tokens: {} in {:.2}s ({:.1} tok/s)\n",
            self.tokens,
            self.wall_s,
            if self.wall_s > 0.0 { self.tokens as f64 / self.wall_s } else { 0.0 },
        ));
        s.push_str(&format!(
            "ttft  ms: p50 {:.2}  p90 {:.2}  p99 {:.2}  mean {:.2}\n",
            q(&self.ttft, 0.50),
            q(&self.ttft, 0.90),
            q(&self.ttft, 0.99),
            self.ttft.mean() * 1e3,
        ));
        s.push_str(&format!(
            "tpot  ms: p50 {:.2}  p90 {:.2}  p99 {:.2}  mean {:.2}\n",
            q(&self.tpot, 0.50),
            q(&self.tpot, 0.90),
            q(&self.tpot, 0.99),
            self.tpot.mean() * 1e3,
        ));
        s.push_str(&format!(
            "e2e   ms: p50 {:.2}  p90 {:.2}  p99 {:.2}  mean {:.2}\n",
            q(&self.e2e, 0.50),
            q(&self.e2e, 0.90),
            q(&self.e2e, 0.99),
            self.e2e.mean() * 1e3,
        ));
        for (name, h) in HOP_NAMES.iter().zip(&self.hop_totals) {
            if h.count() > 0 {
                s.push_str(&format!(
                    "hop {name:<11} ms: p50 {:.2}  p90 {:.2}  p99 {:.2}  mean {:.2}\n",
                    q(h, 0.50),
                    q(h, 0.90),
                    q(h, 0.99),
                    h.mean() * 1e3,
                ));
            }
        }
        s
    }
}

/// Deterministic nonzero trace id for `(seed, sid, turn)` — the low bit
/// is pinned so 0 (the "untraced" sentinel) can never come out.
pub fn trace_id(seed: u64, sid: u64, turn: usize) -> u64 {
    let mut s = stream(seed ^ 0x7ace_7ace, (sid << 24) | turn as u64);
    splitmix64(&mut s) | 1
}

/// One wire-level turn: connect, swallow the greeting, submit traced,
/// collect tokens + the span report.
fn one_turn(
    addr: SocketAddr,
    sid: u64,
    turn: usize,
    delta: Vec<i32>,
    cfg: &LoadConfig,
) -> TurnOutcome {
    let t0 = Instant::now();
    let mut s = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => return TurnOutcome::Transport,
    };
    if s.set_read_timeout(Some(CLIENT_READ_TIMEOUT)).is_err() {
        return TurnOutcome::Transport;
    }
    match wire::read_frame(&mut s) {
        Ok(Frame::Hello { .. }) => {}
        _ => return TurnOutcome::Transport,
    }
    let submit = Frame::SubmitInSession {
        session: sid,
        strict: false,
        max_new: cfg.max_new as u32,
        deadline_ms: cfg.deadline_ms,
        trace: trace_id(cfg.seed, sid, turn),
        profile: true,
        delta,
    };
    if wire::write_frame(&mut s, &submit).is_err() {
        return TurnOutcome::Transport;
    }
    let mut toks = 0usize;
    let mut ttft_s = None;
    let mut hops = Vec::new();
    loop {
        match wire::read_frame(&mut s) {
            Ok(Frame::Token { .. }) => {
                if ttft_s.is_none() {
                    ttft_s = Some(t0.elapsed().as_secs_f64());
                }
                toks += 1;
            }
            Ok(Frame::Spans { hops: h, .. }) => hops = h,
            Ok(Frame::Done { .. }) => {
                let e2e_s = t0.elapsed().as_secs_f64();
                return TurnOutcome::Done {
                    toks,
                    ttft_s: ttft_s.unwrap_or(e2e_s),
                    e2e_s,
                    hops,
                };
            }
            Ok(Frame::Error { code, .. }) => return TurnOutcome::Refused(code),
            _ => return TurnOutcome::Transport,
        }
    }
}

/// Drive one planned session to completion, classifying every outcome.
fn run_session(addr: SocketAddr, cfg: &LoadConfig, sp: &SessionPlan) -> LoadReport {
    let mut rep = LoadReport::default();
    for (t, turn) in sp.turns.iter().enumerate() {
        if turn.think > Duration::ZERO {
            thread::sleep(turn.think);
        }
        match one_turn(addr, sp.sid, t, turn.delta.clone(), cfg) {
            TurnOutcome::Done { toks, ttft_s, e2e_s, hops } => {
                rep.turns_ok += 1;
                rep.tokens += toks as u64;
                rep.ttft.record(ttft_s);
                rep.e2e.record(e2e_s);
                if toks > 1 {
                    rep.tpot.record((e2e_s - ttft_s) / (toks - 1) as f64);
                }
                for hop in &hops {
                    if let Some(i) = HOP_NAMES.iter().position(|n| *n == hop.hop) {
                        rep.hop_totals[i].record(hop.total_us as f64 / 1e6);
                    }
                }
            }
            TurnOutcome::Refused(ErrCode::Overloaded) => rep.refused_overloaded += 1,
            TurnOutcome::Refused(ErrCode::DeadlineExceeded) => rep.refused_deadline += 1,
            TurnOutcome::Refused(_) => rep.refused_other += 1,
            TurnOutcome::Transport => rep.transport_errors += 1,
        }
    }
    rep
}

/// Run the full workload against a front door at `addr`: one worker
/// thread per session, arrivals staggered per the plan, all reports
/// merged into one.
pub fn run(addr: SocketAddr, cfg: &LoadConfig) -> LoadReport {
    let plans = plan(cfg);
    let t0 = Instant::now();
    let workers: Vec<_> = plans
        .into_iter()
        .map(|sp| {
            let cfg = *cfg;
            thread::spawn(move || {
                // hold the arrival schedule against the common start, not
                // against thread-spawn jitter
                if sp.start > Duration::ZERO {
                    thread::sleep(sp.start);
                }
                run_session(addr, &cfg, &sp)
            })
        })
        .collect();
    let mut rep = LoadReport::default();
    for w in workers {
        if let Ok(r) = w.join() {
            rep.absorb(&r);
        } else {
            rep.transport_errors += 1;
        }
    }
    rep.wall_s = t0.elapsed().as_secs_f64();
    rep
}

/// Counter/gauge value by name from a metrics snapshot (0 when absent).
fn metric(snap: &Snapshot, name: &str) -> u64 {
    match snap.entries.get(name) {
        Some(MetricValue::Counter(v)) | Some(MetricValue::Gauge(v)) => *v,
        _ => 0,
    }
}

/// Quantile summary of one latency histogram as a JSON object (ms).
fn hist_json(h: &Hist) -> Json {
    let ms = |v: f64| Json::Num(v * 1e3);
    Json::obj(vec![
        ("count", Json::Int(h.count() as i64)),
        ("mean_ms", ms(h.mean())),
        ("p50_ms", ms(h.quantile(0.50))),
        ("p90_ms", ms(h.quantile(0.90))),
        ("p99_ms", ms(h.quantile(0.99))),
    ])
}

/// Render the run into the checked-in `BENCH_load.json` document:
/// the workload config, client-side latency quantiles and outcome
/// counters, plus the cluster- and front-door-side counters that tell
/// the overload story (retries spent, TTL/spill evictions, sheds).
pub fn bench_doc(
    cfg: &LoadConfig,
    rep: &LoadReport,
    cluster: &Snapshot,
    front: &Snapshot,
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("load".into())),
        (
            "config",
            Json::obj(vec![
                ("sessions", Json::Int(cfg.sessions as i64)),
                ("turns_mean", Json::Int(cfg.turns as i64)),
                ("rate_hz", Json::Num(cfg.rate_hz)),
                ("think_ms_mean", Json::Int(cfg.think_ms as i64)),
                ("prompt_len_mean", Json::Int(cfg.prompt_len as i64)),
                ("max_new", Json::Int(cfg.max_new as i64)),
                ("deadline_ms", Json::Int(cfg.deadline_ms as i64)),
                ("seed", Json::Int(cfg.seed as i64)),
                (
                    "mode",
                    Json::Str(if cfg.rate_hz > 0.0 { "open" } else { "closed" }.into()),
                ),
            ]),
        ),
        (
            "client",
            Json::obj(vec![
                ("turns_ok", Json::Int(rep.turns_ok as i64)),
                ("turns_submitted", Json::Int(rep.turns_submitted() as i64)),
                ("tokens", Json::Int(rep.tokens as i64)),
                ("refused_overloaded", Json::Int(rep.refused_overloaded as i64)),
                ("refused_deadline", Json::Int(rep.refused_deadline as i64)),
                ("refused_other", Json::Int(rep.refused_other as i64)),
                ("transport_errors", Json::Int(rep.transport_errors as i64)),
                ("wall_s", Json::Num(rep.wall_s)),
                (
                    "tokens_per_s",
                    Json::Num(if rep.wall_s > 0.0 {
                        rep.tokens as f64 / rep.wall_s
                    } else {
                        0.0
                    }),
                ),
                ("ttft", hist_json(&rep.ttft)),
                ("tpot", hist_json(&rep.tpot)),
                ("e2e", hist_json(&rep.e2e)),
                (
                    "hops",
                    Json::obj(
                        HOP_NAMES
                            .iter()
                            .zip(&rep.hop_totals)
                            .map(|(n, h)| (*n, hist_json(h)))
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "cluster",
            Json::obj(vec![
                ("retries_total", Json::Int(metric(cluster, "lh_retries_total") as i64)),
                (
                    "session_ttl_evictions_total",
                    Json::Int(metric(cluster, "lh_session_ttl_evictions_total") as i64),
                ),
                (
                    "session_evictions_total",
                    Json::Int(metric(cluster, "lh_session_evictions_total") as i64),
                ),
                (
                    "spill_evictions_total",
                    Json::Int(metric(cluster, "lh_spill_evictions_total") as i64),
                ),
                (
                    "shed_deadline_total",
                    Json::Int(metric(cluster, "lh_shed_deadline_total") as i64),
                ),
                (
                    "shed_overload_total",
                    Json::Int(metric(cluster, "lh_shed_overload_total") as i64),
                ),
                ("session_hits_total", Json::Int(metric(cluster, "lh_session_hits_total") as i64)),
                (
                    "session_misses_total",
                    Json::Int(metric(cluster, "lh_session_misses_total") as i64),
                ),
            ]),
        ),
        (
            "front",
            Json::obj(vec![
                ("requests_total", Json::Int(metric(front, "lh_front_requests_total") as i64)),
                (
                    "shed_deadline_total",
                    Json::Int(metric(front, "lh_front_shed_deadline_total") as i64),
                ),
                (
                    "over_capacity_total",
                    Json::Int(metric(front, "lh_front_over_capacity_total") as i64),
                ),
                ("errors_total", Json::Int(metric(front, "lh_front_errors_total") as i64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LoadConfig {
        LoadConfig {
            sessions: 6,
            turns: 3,
            rate_hz: 8.0,
            think_ms: 20,
            prompt_len: 5,
            max_new: 4,
            deadline_ms: 250,
            seed: 99,
        }
    }

    #[test]
    fn plans_are_deterministic_and_seed_sensitive() {
        let a = plan(&cfg());
        let b = plan(&cfg());
        assert_eq!(a, b, "same config must yield the identical workload");
        let c = plan(&LoadConfig { seed: 100, ..cfg() });
        assert_ne!(a, c, "a different seed must yield a different workload");
        assert_eq!(a.len(), 6);
        for (i, sp) in a.iter().enumerate() {
            assert_eq!(sp.sid, i as u64);
            // turn count uniform on 1..=5 for mean 3
            assert!((1..=5).contains(&sp.turns.len()), "turns {}", sp.turns.len());
            for (t, turn) in sp.turns.iter().enumerate() {
                assert!((1..=9).contains(&turn.delta.len()));
                assert!(turn.delta.iter().all(|&v| (1..=32).contains(&v)));
                if t == 0 {
                    assert_eq!(turn.think, Duration::ZERO, "no think pause before turn 0");
                }
            }
        }
        // open loop: arrivals strictly staggered after session 0
        assert_eq!(a[0].start, Duration::ZERO);
        assert!(a.windows(2).all(|w| w[0].start <= w[1].start));
        assert!(a[5].start > Duration::ZERO);
    }

    #[test]
    fn closed_loop_plans_start_everyone_at_once() {
        let a = plan(&LoadConfig { rate_hz: 0.0, ..cfg() });
        assert!(a.iter().all(|sp| sp.start == Duration::ZERO));
    }

    #[test]
    fn reports_merge_exactly() {
        let mut a = LoadReport::default();
        a.turns_ok = 2;
        a.tokens = 8;
        a.refused_deadline = 1;
        a.ttft.record(0.01);
        a.e2e.record(0.05);
        let mut b = LoadReport::default();
        b.turns_ok = 3;
        b.tokens = 12;
        b.refused_overloaded = 2;
        b.transport_errors = 1;
        b.ttft.record(0.02);
        b.e2e.record(0.06);
        a.hop_totals[4].record(0.001);
        b.hop_totals[4].record(0.002);
        let mut total = LoadReport::default();
        total.absorb(&a);
        total.absorb(&b);
        assert_eq!(total.turns_ok, 5);
        assert_eq!(total.tokens, 20);
        assert_eq!(total.turns_submitted(), 9);
        assert_eq!(total.ttft.count(), 2);
        assert_eq!(total.e2e.count(), 2);
        assert_eq!(total.hop_totals[4].count(), 2, "per-hop hists must merge too");
        let s = total.summary();
        assert!(s.contains("5 ok"), "{s}");
        assert!(s.contains("2 shed overloaded"), "{s}");
        assert!(s.contains("1 shed deadline"), "{s}");
        assert!(s.contains("hop engine"), "recorded hops must render: {s}");
        assert!(!s.contains("hop front"), "empty hop hists stay silent: {s}");
    }

    #[test]
    fn trace_ids_are_deterministic_nonzero_and_distinct() {
        assert_eq!(trace_id(7, 3, 1), trace_id(7, 3, 1));
        assert_ne!(trace_id(7, 3, 1), trace_id(7, 3, 2));
        assert_ne!(trace_id(7, 3, 1), trace_id(7, 4, 1));
        assert_ne!(trace_id(7, 3, 1), trace_id(8, 3, 1));
        for sid in 0..64 {
            for t in 0..8 {
                assert_ne!(trace_id(0, sid, t), 0, "0 is the untraced sentinel");
            }
        }
    }

    #[test]
    fn bench_doc_carries_config_client_and_cluster_sections() {
        let mut rep = LoadReport::default();
        rep.turns_ok = 4;
        rep.tokens = 16;
        rep.wall_s = 2.0;
        rep.ttft.record(0.01);
        rep.hop_totals[0].record(0.004);
        rep.hop_totals[4].record(0.002);
        let mut cluster = Snapshot::default();
        cluster.add_counter("lh_retries_total", 3);
        cluster.add_counter("lh_session_ttl_evictions_total", 2);
        let mut front = Snapshot::default();
        front.add_counter("lh_front_shed_deadline_total", 5);
        let s = bench_doc(&cfg(), &rep, &cluster, &front).to_string_pretty();
        assert!(s.contains("\"bench\": \"load\""), "{s}");
        assert!(s.contains("\"mode\": \"open\""), "{s}");
        assert!(s.contains("\"turns_ok\": 4"), "{s}");
        assert!(s.contains("\"tokens_per_s\": 8"), "{s}");
        assert!(s.contains("\"retries_total\": 3"), "{s}");
        assert!(s.contains("\"session_ttl_evictions_total\": 2"), "{s}");
        assert!(s.contains("\"shed_deadline_total\": 5"), "{s}");
        // a counter missing from the snapshot reads 0, not an error
        assert!(s.contains("\"spill_evictions_total\": 0"), "{s}");
        // per-hop breakdown rides inside "client" with one key per hop
        assert!(s.contains("\"hops\""), "{s}");
        for name in HOP_NAMES {
            assert!(s.contains(&format!("\"{name}\"")), "missing hop {name}: {s}");
        }
    }
}
