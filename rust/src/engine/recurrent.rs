//! LaughingHyena recurrent engine (the paper's deployment target): every
//! long-conv filter is a distilled modal SSM; decode is O(d) per channel
//! per token with constant memory (Lemma 2.2).
//!
//! State layout is structure-of-arrays f32 (re/im planes) — the same layout
//! the L1 `ssm_decode` Pallas kernel uses — so the per-token update is a
//! single linear sweep over `[B, D, d]`.

use super::backbone::Backbone;
use super::shapes::LmShape;
use super::Engine;
use crate::dsp::C64;
use crate::ssm::ModalSsm;
use crate::util::Prng;

/// Per-head modal parameters, broadcast over the head's channels.
struct HeadModal {
    lam_re: Vec<f32>,
    lam_im: Vec<f32>,
    r_re: Vec<f32>,
    r_im: Vec<f32>,
    h0: f32,
}

pub struct RecurrentEngine {
    bb: Backbone,
    /// modal params per layer per head.
    modal: Vec<Vec<HeadModal>>,
    d_state: usize,
    batch: usize,
    // generation state
    /// [B][layer][D * d] interleaved per channel, re and im planes.
    x_re: Vec<Vec<Vec<f32>>>,
    x_im: Vec<Vec<Vec<f32>>>,
    /// short-conv rolling buffers [B][layer][3D * (kw-1)].
    sc: Vec<Vec<Vec<f32>>>,
    last: Vec<i32>,
}

impl RecurrentEngine {
    /// Build with synthetic distilled filters (random stable modal systems
    /// per head — the engines benchmark cost, not quality).
    pub fn new(shape: &LmShape, batch: usize, seed: u64) -> RecurrentEngine {
        let bb = Backbone::new(shape, seed);
        let mut rng = Prng::new(seed ^ 0xD15711);
        let d_state = shape.d_state;
        let modal = (0..shape.n_layer)
            .map(|_| {
                (0..shape.heads)
                    .map(|_| {
                        let sys = random_modal(&mut rng, d_state);
                        HeadModal {
                            lam_re: sys.poles.iter().map(|p| p.re as f32).collect(),
                            lam_im: sys.poles.iter().map(|p| p.im as f32).collect(),
                            r_re: sys.residues.iter().map(|r| r.re as f32).collect(),
                            r_im: sys.residues.iter().map(|r| r.im as f32).collect(),
                            h0: sys.h0 as f32,
                        }
                    })
                    .collect()
            })
            .collect();
        let d = shape.d_model;
        let kw = shape.short_kw;
        RecurrentEngine {
            bb,
            modal,
            d_state,
            batch,
            x_re: vec![vec![vec![0.0; d * d_state]; shape.n_layer]; batch],
            x_im: vec![vec![vec![0.0; d * d_state]; shape.n_layer]; batch],
            sc: vec![vec![vec![0.0; 3 * d * (kw - 1)]; shape.n_layer]; batch],
            last: vec![0; batch],
        }
    }

    /// Zero the generation state of one batch row (slot recycling).
    pub fn reset_row(&mut self, b: usize) {
        for l in 0..self.bb.shape.n_layer {
            self.x_re[b][l].fill(0.0);
            self.x_im[b][l].fill(0.0);
            self.sc[b][l].fill(0.0);
        }
        self.last[b] = 0;
    }

    /// Prefill a single batch row with a prompt; returns the first greedy
    /// token. Rows are independent — this is the continuous-batching hook.
    pub fn prefill_row(&mut self, b: usize, prompt: &[i32]) -> i32 {
        self.reset_row(b);
        let Self { bb, modal, x_re, x_im, sc, d_state, last, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        let mut logits = vec![0.0f32; bb.shape.vocab];
        let (xr_b, xi_b, sc_b) = (&mut x_re[b], &mut x_im[b], &mut sc[b]);
        for &tok in prompt {
            logits = bb.decode_one(tok, |li, qkv| {
                mix_one(d, kw, group, *d_state, &modal[li], &mut sc_b[li],
                        &mut xr_b[li], &mut xi_b[li], qkv)
            });
        }
        let next = bb.greedy(&logits);
        last[b] = next;
        next
    }

    /// One decode step for a single row.
    pub fn decode_row(&mut self, b: usize) -> i32 {
        let Self { bb, modal, x_re, x_im, sc, d_state, last, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        let tok = last[b];
        let (xr_b, xi_b, sc_b) = (&mut x_re[b], &mut x_im[b], &mut sc[b]);
        let logits = bb.decode_one(tok, |li, qkv| {
            mix_one(d, kw, group, *d_state, &modal[li], &mut sc_b[li],
                    &mut xr_b[li], &mut xi_b[li], qkv)
        });
        let next = bb.greedy(&logits);
        last[b] = next;
        next
    }

    /// Bytes of generation state one slot costs.
    pub fn bytes_per_row(&self) -> u64 {
        self.state_bytes() / self.batch as u64
    }

    /// Replace the synthetic modal systems of one layer (distillery output).
    pub fn set_layer_modal(&mut self, layer: usize, systems: &[ModalSsm]) {
        assert_eq!(systems.len(), self.bb.shape.heads);
        self.modal[layer] = systems
            .iter()
            .map(|sys| HeadModal {
                lam_re: sys.poles.iter().map(|p| p.re as f32).collect(),
                lam_im: sys.poles.iter().map(|p| p.im as f32).collect(),
                r_re: sys.residues.iter().map(|r| r.re as f32).collect(),
                r_im: sys.residues.iter().map(|r| r.im as f32).collect(),
                h0: sys.h0 as f32,
            })
            .collect();
    }

}

/// Fused short-conv + gated SSM mixer for one token of one sequence.
/// Free function so the backbone (&) and generation state (&mut) borrows
/// stay disjoint.
#[allow(clippy::too_many_arguments)]
fn mix_one(
    d: usize,
    kw: usize,
    group: usize,
    ds: usize,
    modal_layer: &[HeadModal],
    buf: &mut [f32],
    xr: &mut [f32],
    xi: &mut [f32],
    qkv: &[f32],
) -> Vec<f32> {
    // short conv: fixed causal taps (engines measure cost; the AOT path
    // carries learned taps)
    let mut qkv_c = vec![0.0f32; 3 * d];
    let w: [f32; 3] = [0.25, 0.35, 0.4];
    for c in 0..3 * d {
        let mut acc = w[kw - 1] * qkv[c];
        for j in 0..kw - 1 {
            acc += w[j] * buf[c * (kw - 1) + j];
        }
        qkv_c[c] = acc;
        // roll buffer
        for j in 0..kw - 2 {
            buf[c * (kw - 1) + j] = buf[c * (kw - 1) + j + 1];
        }
        buf[c * (kw - 1) + kw - 2] = qkv[c];
    }
    let (q, rest) = qkv_c.split_at(d);
    let (k, v) = rest.split_at(d);
    // gated SSM update per channel
    let mut y = vec![0.0f32; d];
    for c in 0..d {
        let head = &modal_layer[c / group];
        let u = k[c] * v[c];
        let base = c * ds;
        let mut acc = head.h0 * u;
        for n in 0..ds {
            let (re, im) = (xr[base + n], xi[base + n]);
            acc += head.r_re[n] * re - head.r_im[n] * im;
            let nr = head.lam_re[n] * re - head.lam_im[n] * im + u;
            let ni = head.lam_re[n] * im + head.lam_im[n] * re;
            xr[base + n] = nr;
            xi[base + n] = ni;
        }
        y[c] = q[c] * acc;
    }
    y
}

fn random_modal(rng: &mut Prng, d: usize) -> ModalSsm {
    let pairs: Vec<(C64, C64)> = (0..d / 2)
        .map(|_| {
            (
                C64::polar(rng.range(0.5, 0.95), rng.range(0.1, 2.9)),
                C64::new(rng.normal() * 0.2, rng.normal() * 0.2),
            )
        })
        .collect();
    ModalSsm::from_conjugate_pairs(&pairs, rng.normal() * 0.1)
}

impl Engine for RecurrentEngine {
    fn name(&self) -> &'static str {
        "laughing-hyena"
    }

    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Vec<i32> {
        assert_eq!(prompts.len(), self.batch);
        // reset state
        for b in 0..self.batch {
            for l in 0..self.bb.shape.n_layer {
                self.x_re[b][l].fill(0.0);
                self.x_im[b][l].fill(0.0);
                self.sc[b][l].fill(0.0);
            }
        }
        let batch = self.batch;
        let mut out = Vec::with_capacity(batch);
        let Self { bb, modal, x_re, x_im, sc, d_state, last, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        for b in 0..batch {
            // consume the prompt through the recurrence (O(T d) state init;
            // the FFT variant is benchmarked at the filter level)
            let mut logits = vec![0.0f32; bb.shape.vocab];
            let (xr_b, xi_b, sc_b) = (&mut x_re[b], &mut x_im[b], &mut sc[b]);
            for &tok in &prompts[b] {
                logits = bb.decode_one(tok, |li, qkv| {
                    mix_one(d, kw, group, *d_state, &modal[li], &mut sc_b[li],
                            &mut xr_b[li], &mut xi_b[li], qkv)
                });
            }
            let next = bb.greedy(&logits);
            last[b] = next;
            out.push(next);
        }
        out
    }

    fn decode(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch);
        let Self { bb, modal, x_re, x_im, sc, d_state, last, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        for b in 0..last.len() {
            let tok = last[b];
            let (xr_b, xi_b, sc_b) = (&mut x_re[b], &mut x_im[b], &mut sc[b]);
            let logits = bb.decode_one(tok, |li, qkv| {
                mix_one(d, kw, group, *d_state, &modal[li], &mut sc_b[li],
                        &mut xr_b[li], &mut xi_b[li], qkv)
            });
            let next = bb.greedy(&logits);
            last[b] = next;
            out.push(next);
        }
        out
    }

    fn state_bytes(&self) -> u64 {
        let shape = &self.bb.shape;
        let per_seq = shape.n_layer
            * (2 * shape.d_model * self.d_state // re+im state
                + 3 * shape.d_model * (shape.short_kw - 1));
        (self.batch * per_seq * 4) as u64
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_generation;

    #[test]
    fn generates_tokens_in_vocab() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 2, 7);
        let prompts = vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]];
        let first = eng.prefill(&prompts);
        assert_eq!(first.len(), 2);
        for _ in 0..4 {
            let toks = eng.decode();
            assert!(toks.iter().all(|&t| (t as usize) < shape.vocab));
        }
    }

    #[test]
    fn state_is_constant_during_generation() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 1, 7);
        let r = run_generation(&mut eng, &[vec![1; 16]], 8);
        let expected = eng.state_bytes();
        assert_eq!(r.peak_state_bytes, expected, "O(d) memory must not grow");
    }

    #[test]
    fn deterministic_given_seed() {
        let shape = LmShape::bench("nano").unwrap();
        let mut e1 = RecurrentEngine::new(&shape, 1, 3);
        let mut e2 = RecurrentEngine::new(&shape, 1, 3);
        let p = vec![vec![2, 4, 6]];
        assert_eq!(e1.prefill(&p), e2.prefill(&p));
        assert_eq!(e1.decode(), e2.decode());
    }
}
