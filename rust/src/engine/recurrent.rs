//! LaughingHyena recurrent engine (the paper's deployment target): every
//! long-conv filter is a distilled modal SSM; decode is O(d) per channel
//! per token with constant memory (Lemma 2.2).
//!
//! State layout is structure-of-arrays f32 (re/im planes) — the same layout
//! the L1 `ssm_decode` Pallas kernel uses — so the per-token update is a
//! single linear sweep over `[B, D, d]`.

use super::backbone::Backbone;
use super::shapes::LmShape;
use super::Engine;
use crate::dsp::C64;
use crate::session::{SessionError, SessionState};
use crate::ssm::ModalSsm;
use crate::util::pool::Pool;
use crate::util::Prng;

/// Engine tag stamped into [`SessionState`] snapshots.
pub const STATE_TAG: &str = "laughing-hyena";

/// Per-head modal parameters, broadcast over the head's channels.
struct HeadModal {
    lam_re: Vec<f32>,
    lam_im: Vec<f32>,
    r_re: Vec<f32>,
    r_im: Vec<f32>,
    h0: f32,
}

impl HeadModal {
    fn from_ssm(sys: &ModalSsm) -> HeadModal {
        HeadModal {
            lam_re: sys.poles.iter().map(|p| p.re as f32).collect(),
            lam_im: sys.poles.iter().map(|p| p.im as f32).collect(),
            r_re: sys.residues.iter().map(|r| r.re as f32).collect(),
            r_im: sys.residues.iter().map(|r| r.im as f32).collect(),
            h0: sys.h0 as f32,
        }
    }
}

pub struct RecurrentEngine {
    bb: Backbone,
    /// modal params per layer per head.
    modal: Vec<Vec<HeadModal>>,
    d_state: usize,
    batch: usize,
    // generation state
    /// [B][layer][D * d] interleaved per channel, re and im planes.
    x_re: Vec<Vec<Vec<f32>>>,
    x_im: Vec<Vec<Vec<f32>>>,
    /// short-conv rolling buffers [B][layer][3D * (kw-1)].
    sc: Vec<Vec<Vec<f32>>>,
    last: Vec<i32>,
}

impl RecurrentEngine {
    /// Build with synthetic distilled filters (random stable modal systems
    /// per head — the engines benchmark cost, not quality).
    ///
    /// Setup fans out over [`Pool`] per (layer, head); each head draws its
    /// modal system from its own derived seed, so construction is
    /// deterministic at any thread count.
    pub fn new(shape: &LmShape, batch: usize, seed: u64) -> RecurrentEngine {
        let bb = Backbone::new(shape, seed);
        let d_state = shape.d_state;
        let head_jobs: Vec<usize> = (0..shape.n_layer * shape.heads).collect();
        let flat = Pool::auto().map(head_jobs, |idx| {
            let mut rng = Prng::derived(seed ^ 0xD15711, idx as u64);
            HeadModal::from_ssm(&random_modal(&mut rng, d_state))
        });
        let mut modal: Vec<Vec<HeadModal>> = Vec::with_capacity(shape.n_layer);
        let mut it = flat.into_iter();
        for _ in 0..shape.n_layer {
            modal.push((0..shape.heads).map(|_| it.next().expect("head modal")).collect());
        }
        let d = shape.d_model;
        let kw = shape.short_kw;
        RecurrentEngine {
            bb,
            modal,
            d_state,
            batch,
            x_re: vec![vec![vec![0.0; d * d_state]; shape.n_layer]; batch],
            x_im: vec![vec![vec![0.0; d * d_state]; shape.n_layer]; batch],
            sc: vec![vec![vec![0.0; 3 * d * (kw - 1)]; shape.n_layer]; batch],
            last: vec![0; batch],
        }
    }

    /// Zero the generation state of one batch row (slot recycling).
    pub fn reset_row(&mut self, b: usize) {
        reset_row_bufs(&mut self.x_re[b], &mut self.x_im[b], &mut self.sc[b]);
        self.last[b] = 0;
    }

    /// Prefill a single batch row with a prompt; returns the first greedy
    /// token. Rows are independent — this is the continuous-batching hook.
    pub fn prefill_row(&mut self, b: usize, prompt: &[i32]) -> i32 {
        let mut wanted: Vec<Option<&[i32]>> = vec![None; self.batch];
        wanted[b] = Some(prompt);
        self.prefill_wanted(&wanted)[0].1
    }

    /// Prefill several (slot, prompt) jobs, fanning the independent rows out
    /// over [`Pool`] workers — the coordinator's batched-prefill hot path.
    /// Returns (slot, first greedy token) pairs in ascending slot order.
    pub fn prefill_rows(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        let mut wanted: Vec<Option<&[i32]>> = vec![None; self.batch];
        for (slot, prompt) in jobs {
            wanted[*slot] = Some(prompt.as_slice());
        }
        self.prefill_wanted(&wanted)
    }

    /// Shared pooled prefill core: rows with a `Some(prompt)` entry are
    /// reset and consumed in parallel (each row owns disjoint state).
    fn prefill_wanted(&mut self, wanted: &[Option<&[i32]>]) -> Vec<(usize, i32)> {
        self.run_wanted(wanted, true)
    }

    /// Feed several (slot, tokens) jobs *without* resetting the rows,
    /// fanned out over the pool — the coordinator's batched session-resume
    /// hot path (same per-row math as [`RecurrentEngine::feed_row`]).
    pub fn feed_rows(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        let mut wanted: Vec<Option<&[i32]>> = vec![None; self.batch];
        for (slot, tokens) in jobs {
            wanted[*slot] = Some(tokens.as_slice());
        }
        self.run_wanted(&wanted, false)
    }

    /// Pooled multi-row token ingestion; `reset` distinguishes prefill
    /// (fresh rows) from session resume (continue from restored state).
    fn run_wanted(&mut self, wanted: &[Option<&[i32]>], reset: bool) -> Vec<(usize, i32)> {
        let Self { bb, modal, x_re, x_im, sc, d_state, last, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        let ds = *d_state;
        let bb = &*bb;
        let modal = &*modal;
        let rows: Vec<_> = x_re
            .iter_mut()
            .zip(x_im.iter_mut())
            .zip(sc.iter_mut())
            .zip(last.iter_mut())
            .enumerate()
            .filter_map(|(b, (((xr, xi), sc_b), last_b))| {
                wanted[b].map(|prompt| (b, xr, xi, sc_b, last_b, prompt))
            })
            .collect();
        Pool::auto().map(rows, |(b, xr, xi, sc_b, last_b, prompt)| {
            if reset {
                reset_row_bufs(xr, xi, sc_b);
            }
            let fallback = if reset { 0 } else { *last_b };
            let next = consume_row(bb, modal, d, kw, group, ds, sc_b, xr, xi, prompt, fallback);
            *last_b = next;
            (b, next)
        })
    }

    /// One decode step for a single row.
    pub fn decode_row(&mut self, b: usize) -> i32 {
        let tok = self.last[b];
        self.feed_row(b, &[tok])
    }

    /// Feed tokens through one row *without* resetting it — the session
    /// resume hook.  Starting from a restored snapshot, feeding the
    /// snapshot's pending `last_token` followed by the new turn's tokens is
    /// arithmetically identical to prefilling the whole transcript from
    /// scratch (same per-token op sequence), which is what makes resumed
    /// sessions bit-exact.  Returns the greedy token after the last fed
    /// token (the row's `last` if `tokens` is empty).
    pub fn feed_row(&mut self, b: usize, tokens: &[i32]) -> i32 {
        let Self { bb, modal, x_re, x_im, sc, d_state, last, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        let next = consume_row(
            bb, modal, d, kw, group, *d_state,
            &mut sc[b], &mut x_re[b], &mut x_im[b], tokens, last[b],
        );
        last[b] = next;
        next
    }

    /// Extract one row's full per-layer SSM + short-conv state as a
    /// versioned [`SessionState`] blob (O(d) bytes, independent of how many
    /// tokens the row has consumed — Lemma 2.2 is what makes sessions
    /// cheap).
    pub fn snapshot_row(&self, b: usize) -> SessionState {
        let flat = |layers: &[Vec<f32>]| -> Vec<f32> {
            layers.iter().flat_map(|l| l.iter().copied()).collect()
        };
        let mut st = SessionState::new(STATE_TAG, self.last[b]);
        st.push_plane("x_re", flat(&self.x_re[b]));
        st.push_plane("x_im", flat(&self.x_im[b]));
        st.push_plane("sc", flat(&self.sc[b]));
        st
    }

    /// Reinstall a snapshot into one row, validating engine tag and shape.
    pub fn restore_row(&mut self, b: usize, st: &SessionState) -> Result<(), SessionError> {
        st.check_engine(STATE_TAG)?;
        let shape = &self.bb.shape;
        let x_len = shape.n_layer * shape.d_model * self.d_state;
        let sc_len = shape.n_layer * 3 * shape.d_model * (shape.short_kw - 1);
        let x_re = st.plane_checked("x_re", x_len)?;
        let x_im = st.plane_checked("x_im", x_len)?;
        let sc = st.plane_checked("sc", sc_len)?;
        let unflat = |flat: &[f32], layers: &mut [Vec<f32>]| {
            let mut off = 0;
            for l in layers {
                l.copy_from_slice(&flat[off..off + l.len()]);
                off += l.len();
            }
        };
        unflat(x_re, &mut self.x_re[b]);
        unflat(x_im, &mut self.x_im[b]);
        unflat(sc, &mut self.sc[b]);
        self.last[b] = st.last_token;
        Ok(())
    }

    /// Bytes of generation state one slot costs.
    pub fn bytes_per_row(&self) -> u64 {
        self.state_bytes() / self.batch as u64
    }

    /// Replace the synthetic modal systems of one layer (distillery output).
    pub fn set_layer_modal(&mut self, layer: usize, systems: &[ModalSsm]) {
        assert_eq!(systems.len(), self.bb.shape.heads);
        self.modal[layer] = systems.iter().map(HeadModal::from_ssm).collect();
    }
}

/// Zero one row's per-layer generation buffers — the single reset site
/// shared by [`RecurrentEngine::reset_row`] and the pooled prefill (add any
/// new per-row state buffer here so slot recycling can't go stale).
fn reset_row_bufs(xr: &mut [Vec<f32>], xi: &mut [Vec<f32>], sc: &mut [Vec<f32>]) {
    for l in 0..xr.len() {
        xr[l].fill(0.0);
        xi[l].fill(0.0);
        sc[l].fill(0.0);
    }
}

/// Feed `tokens` through one row's recurrence (no reset) and return the
/// greedy token after the last one (`fallback` when `tokens` is empty).
/// The single per-token path shared by prefill, decode and session resume —
/// sharing it is what guarantees the three produce identical arithmetic.
#[allow(clippy::too_many_arguments)]
fn consume_row(
    bb: &Backbone,
    modal: &[Vec<HeadModal>],
    d: usize,
    kw: usize,
    group: usize,
    ds: usize,
    sc_b: &mut [Vec<f32>],
    xr: &mut [Vec<f32>],
    xi: &mut [Vec<f32>],
    tokens: &[i32],
    fallback: i32,
) -> i32 {
    if tokens.is_empty() {
        return fallback;
    }
    let mut logits = Vec::new();
    for &tok in tokens {
        logits = bb.decode_one(tok, |li, qkv| {
            mix_one(d, kw, group, ds, &modal[li], &mut sc_b[li], &mut xr[li], &mut xi[li], qkv)
        });
    }
    bb.greedy(&logits)
}

/// Fused short-conv + gated SSM mixer for one token of one sequence.
/// Free function so the backbone (&) and generation state (&mut) borrows
/// stay disjoint.
#[allow(clippy::too_many_arguments)]
fn mix_one(
    d: usize,
    kw: usize,
    group: usize,
    ds: usize,
    modal_layer: &[HeadModal],
    buf: &mut [f32],
    xr: &mut [f32],
    xi: &mut [f32],
    qkv: &[f32],
) -> Vec<f32> {
    // short conv: fixed causal taps (engines measure cost; the AOT path
    // carries learned taps)
    let mut qkv_c = vec![0.0f32; 3 * d];
    let w: [f32; 3] = [0.25, 0.35, 0.4];
    for c in 0..3 * d {
        let mut acc = w[kw - 1] * qkv[c];
        for j in 0..kw - 1 {
            acc += w[j] * buf[c * (kw - 1) + j];
        }
        qkv_c[c] = acc;
        // roll buffer
        for j in 0..kw - 2 {
            buf[c * (kw - 1) + j] = buf[c * (kw - 1) + j + 1];
        }
        buf[c * (kw - 1) + kw - 2] = qkv[c];
    }
    let (q, rest) = qkv_c.split_at(d);
    let (k, v) = rest.split_at(d);
    // gated SSM update per channel
    let mut y = vec![0.0f32; d];
    for c in 0..d {
        let head = &modal_layer[c / group];
        let u = k[c] * v[c];
        let base = c * ds;
        let mut acc = head.h0 * u;
        for n in 0..ds {
            let (re, im) = (xr[base + n], xi[base + n]);
            acc += head.r_re[n] * re - head.r_im[n] * im;
            let nr = head.lam_re[n] * re - head.lam_im[n] * im + u;
            let ni = head.lam_re[n] * im + head.lam_im[n] * re;
            xr[base + n] = nr;
            xi[base + n] = ni;
        }
        y[c] = q[c] * acc;
    }
    y
}

fn random_modal(rng: &mut Prng, d: usize) -> ModalSsm {
    let pairs: Vec<(C64, C64)> = (0..d / 2)
        .map(|_| {
            (
                C64::polar(rng.range(0.5, 0.95), rng.range(0.1, 2.9)),
                C64::new(rng.normal() * 0.2, rng.normal() * 0.2),
            )
        })
        .collect();
    ModalSsm::from_conjugate_pairs(&pairs, rng.normal() * 0.1)
}

impl Engine for RecurrentEngine {
    fn name(&self) -> &'static str {
        "laughing-hyena"
    }

    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Vec<i32> {
        assert_eq!(prompts.len(), self.batch);
        // consume every prompt through the recurrence (O(T d) state init;
        // the FFT variant is benchmarked at the filter level), with the
        // independent rows fanned out over the pool
        let wanted: Vec<Option<&[i32]>> =
            prompts.iter().map(|p| Some(p.as_slice())).collect();
        let firsts = self.prefill_wanted(&wanted);
        let mut out = vec![0i32; prompts.len()];
        for (slot, tok) in firsts {
            out[slot] = tok;
        }
        out
    }

    fn decode(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch);
        let Self { bb, modal, x_re, x_im, sc, d_state, last, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        for b in 0..last.len() {
            let tok = last[b];
            let (xr_b, xi_b, sc_b) = (&mut x_re[b], &mut x_im[b], &mut sc[b]);
            let logits = bb.decode_one(tok, |li, qkv| {
                mix_one(d, kw, group, *d_state, &modal[li], &mut sc_b[li],
                        &mut xr_b[li], &mut xi_b[li], qkv)
            });
            let next = bb.greedy(&logits);
            last[b] = next;
            out.push(next);
        }
        out
    }

    fn state_bytes(&self) -> u64 {
        let shape = &self.bb.shape;
        let per_seq = shape.n_layer
            * (2 * shape.d_model * self.d_state // re+im state
                + 3 * shape.d_model * (shape.short_kw - 1));
        (self.batch * per_seq * 4) as u64
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_generation;

    #[test]
    fn generates_tokens_in_vocab() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 2, 7);
        let prompts = vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]];
        let first = eng.prefill(&prompts);
        assert_eq!(first.len(), 2);
        for _ in 0..4 {
            let toks = eng.decode();
            assert!(toks.iter().all(|&t| (t as usize) < shape.vocab));
        }
    }

    #[test]
    fn state_is_constant_during_generation() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 1, 7);
        let r = run_generation(&mut eng, &[vec![1; 16]], 8);
        let expected = eng.state_bytes();
        assert_eq!(r.peak_state_bytes, expected, "O(d) memory must not grow");
    }

    #[test]
    fn deterministic_given_seed() {
        let shape = LmShape::bench("nano").unwrap();
        let mut e1 = RecurrentEngine::new(&shape, 1, 3);
        let mut e2 = RecurrentEngine::new(&shape, 1, 3);
        let p = vec![vec![2, 4, 6]];
        assert_eq!(e1.prefill(&p), e2.prefill(&p));
        assert_eq!(e1.decode(), e2.decode());
    }

    #[test]
    fn snapshot_restore_resume_is_bit_identical() {
        // generate, snapshot mid-stream, keep generating on A; restore the
        // snapshot into a *different* engine row and replay — every token
        // must match bit-for-bit.
        let shape = LmShape::bench("nano").unwrap();
        let mut a = RecurrentEngine::new(&shape, 2, 13);
        a.prefill_row(0, &[3, 1, 4, 1, 5]);
        for _ in 0..3 {
            a.decode_row(0);
        }
        let snap = a.snapshot_row(0);
        let cont_a: Vec<i32> = (0..6).map(|_| a.decode_row(0)).collect();
        let mut b = RecurrentEngine::new(&shape, 2, 13);
        b.restore_row(1, &snap).unwrap();
        let cont_b: Vec<i32> = (0..6).map(|_| b.decode_row(1)).collect();
        assert_eq!(cont_a, cont_b);
    }

    #[test]
    fn feed_without_reset_matches_fresh_prefill_of_transcript() {
        // resume semantics: state(prefix) + feed(rest) == prefill(prefix ++ rest)
        let shape = LmShape::bench("nano").unwrap();
        let prefix = vec![7, 8, 9, 2];
        let rest = vec![4, 4, 1];
        let mut split = RecurrentEngine::new(&shape, 1, 5);
        split.prefill_row(0, &prefix);
        let first_split = split.feed_row(0, &rest);
        let mut whole = RecurrentEngine::new(&shape, 1, 5);
        let mut full = prefix.clone();
        full.extend_from_slice(&rest);
        let first_whole = whole.prefill_row(0, &full);
        assert_eq!(first_split, first_whole);
        for _ in 0..5 {
            assert_eq!(split.decode_row(0), whole.decode_row(0));
        }
    }

    #[test]
    fn pooled_feed_rows_matches_row_by_row() {
        // the batched session-resume path must agree exactly with feeding
        // each row on its own
        let shape = LmShape::bench("nano").unwrap();
        let mut pooled = RecurrentEngine::new(&shape, 3, 21);
        let mut serial = RecurrentEngine::new(&shape, 3, 21);
        for b in 0..3 {
            pooled.prefill_row(b, &[1 + b as i32, 5, 9]);
            serial.prefill_row(b, &[1 + b as i32, 5, 9]);
        }
        let jobs: Vec<(usize, Vec<i32>)> =
            (0..3).map(|b| (b, vec![2 + b as i32, 4])).collect();
        let batch = pooled.feed_rows(&jobs);
        let mut row_by_row = vec![];
        for (b, toks) in &jobs {
            row_by_row.push((*b, serial.feed_row(*b, toks)));
        }
        assert_eq!(batch, row_by_row);
        for _ in 0..3 {
            assert_eq!(pooled.decode(), serial.decode());
        }
    }

    #[test]
    fn restore_rejects_foreign_and_misshapen_blobs() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 1, 5);
        let mut snap = eng.snapshot_row(0);
        snap.engine = "transformer".into();
        assert!(eng.restore_row(0, &snap).is_err());
        let mut snap2 = eng.snapshot_row(0);
        snap2.planes[0].data.pop();
        assert!(eng.restore_row(0, &snap2).is_err());
    }

    #[test]
    fn pooled_prefill_matches_row_by_row() {
        // the pooled batch prefill must agree exactly with prefilling each
        // row on its own (rows are independent by construction)
        let shape = LmShape::bench("nano").unwrap();
        let prompts = vec![vec![1, 2, 3, 4], vec![9, 8, 7], vec![5; 6], vec![2, 2]];
        let mut pooled = RecurrentEngine::new(&shape, 4, 21);
        let mut serial = RecurrentEngine::new(&shape, 4, 21);
        let batch_first = pooled.prefill(&prompts);
        let mut row_first = Vec::new();
        for (b, p) in prompts.iter().enumerate() {
            row_first.push(serial.prefill_row(b, p));
        }
        assert_eq!(batch_first, row_first);
        for _ in 0..4 {
            assert_eq!(pooled.decode(), serial.decode());
        }
    }
}
