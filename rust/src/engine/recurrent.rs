//! LaughingHyena recurrent engine (the paper's deployment target): every
//! long-conv filter is a distilled modal SSM; decode is O(d) per channel
//! per token with constant memory (Lemma 2.2).
//!
//! The token step is the hottest loop in the repo, so its data layout is
//! built for it:
//!
//! * **Flat SoA state.**  Each batch row owns one contiguous allocation per
//!   plane (`x_re` / `x_im` over `[n_layer * D * d]`, short-conv window
//!   over `[n_layer * 3D * (kw-1)]`) — the same structure-of-arrays layout
//!   the L1 `ssm_decode` Pallas kernel uses — so the per-token update is a
//!   single linear sweep with no per-layer pointer chasing.
//! * **Interleaved modal plane.**  Per layer the modal parameters are
//!   pre-broadcast to channel order as `[lam_re, lam_im, r_re, r_im]`
//!   quadruples, so the `[D, d]` sweep is one contiguous pass with no
//!   per-channel head lookup or division.
//! * **Circular short-conv windows.**  The `kw-1` retained inputs per
//!   channel are indexed by a per-row cursor instead of memmove-shifted on
//!   every token; `kw == 1` degenerates to no window at all.
//! * **Engine-owned scratch.**  Every per-token intermediate (backbone
//!   buffers, logits, short-conv output) lives in per-row
//!   [`RowScratch`], so `mix_one` / `consume_row` /
//!   [`Backbone::decode_one`] perform zero heap allocations in steady
//!   state, and [`RecurrentEngine::decode_rows`] can fan rows out over the
//!   [`Pool`] without contention — decode parallelizes like prefill
//!   already did.  The pool's workers are persistent (parked between
//!   steps), so the per-step fan-out costs a handoff, not thread spawns.
//! * **Vectorized modal sweep.**  The per-channel contraction + state
//!   update runs through [`super::modal_sweep::sweep`]: a lane-structured
//!   kernel (auto-vectorizable on stable Rust) with an AVX2 path behind
//!   `--features simd`, bit-identical to the scalar kernel by
//!   construction.

use std::time::Instant;

use super::backbone::{Backbone, DecodeScratch, StageTimes};
use super::shapes::{LmShape, SHORT_TAPS};
use super::Engine;
use crate::dsp::C64;
use crate::session::{SessionError, SessionState};
use crate::ssm::ModalSsm;
use crate::util::pool::Pool;
use crate::util::Prng;

/// Engine tag stamped into [`SessionState`] snapshots.
pub const STATE_TAG: &str = "laughing-hyena";

/// Per-layer modal parameters, pre-broadcast to channel order: for channel
/// `c` and mode `n`, `plane[(c * d_state + n) * 4 ..][..4]` holds
/// `[lam_re, lam_im, r_re, r_im]` of head `c / (d_model / heads)`.
struct LayerModal {
    /// Interleaved quadruples, `[D * d_state * 4]`.
    plane: Vec<f32>,
    /// Per-channel passthrough tap, `[D]`.
    h0: Vec<f32>,
}

impl LayerModal {
    /// Broadcast one layer's per-head systems over their channel groups.
    fn from_heads(heads: &[ModalSsm], d_model: usize, d_state: usize) -> LayerModal {
        let group = d_model / heads.len();
        let mut plane = Vec::with_capacity(d_model * d_state * 4);
        let mut h0 = Vec::with_capacity(d_model);
        for c in 0..d_model {
            let sys = &heads[c / group];
            assert_eq!(
                sys.order(),
                d_state,
                "modal system order must match the shape's d_state"
            );
            for n in 0..d_state {
                plane.push(sys.poles[n].re as f32);
                plane.push(sys.poles[n].im as f32);
                plane.push(sys.residues[n].re as f32);
                plane.push(sys.residues[n].im as f32);
            }
            h0.push(sys.h0 as f32);
        }
        LayerModal { plane, h0 }
    }
}

/// Per-row decode scratch: the backbone's token buffers plus the fused
/// mixer's short-conv output.  One per slot, so pooled decode workers never
/// share a buffer.
struct RowScratch {
    bb: DecodeScratch,
    /// Short-conv output [3D].
    qkv_c: Vec<f32>,
    /// Per-stage profiling aggregates, populated only while the row is
    /// marked profiled (plain `Copy` counters — recording allocates
    /// nothing and rows never share them).
    times: StageTimes,
}

impl RowScratch {
    fn new(shape: &LmShape) -> RowScratch {
        RowScratch {
            bb: DecodeScratch::new(shape),
            qkv_c: vec![0.0; 3 * shape.d_model],
            times: StageTimes::default(),
        }
    }
}

pub struct RecurrentEngine {
    bb: Backbone,
    /// Pre-broadcast modal params per layer.
    modal: Vec<LayerModal>,
    d_state: usize,
    batch: usize,
    // generation state: one contiguous allocation per row per plane
    /// SSM state planes, `[B]` rows of `[n_layer * D * d_state]`.
    x_re: Vec<Vec<f32>>,
    x_im: Vec<Vec<f32>>,
    /// Short-conv windows, `[B]` rows of `[n_layer * 3D * (kw-1)]`,
    /// circularly indexed by `sc_pos`.
    sc: Vec<Vec<f32>>,
    /// Circular cursor into every `kw-1`-slot channel window of `sc`.  All
    /// layers and channels of a row advance in lockstep (one step per
    /// token), so a single cursor per row suffices; the element at offset
    /// `(sc_pos + j) % (kw-1)` of a window is the j-th oldest retained
    /// input.  Snapshots linearize to oldest-first order, which keeps
    /// [`SessionState`] blobs byte-identical to the pre-circular format.
    sc_pos: Vec<usize>,
    last: Vec<i32>,
    /// Per-row decode scratch (index-aligned with the state rows).
    scratch: Vec<RowScratch>,
    /// Per-row profiling flags: a profiled row routes its tokens
    /// through the timed twin of the hot path (same statements, same
    /// order — bit-identical output); an unprofiled row pays exactly
    /// one branch per token, as before.
    profiled: Vec<bool>,
}

impl RecurrentEngine {
    /// Build with synthetic distilled filters (random stable modal systems
    /// per head — the engines benchmark cost, not quality).
    ///
    /// Setup fans out over [`Pool`] per (layer, head); each head draws its
    /// modal system from its own derived seed, so construction is
    /// deterministic at any thread count.
    pub fn new(shape: &LmShape, batch: usize, seed: u64) -> RecurrentEngine {
        let bb = Backbone::new(shape, seed);
        let d_state = shape.d_state;
        let head_jobs: Vec<usize> = (0..shape.n_layer * shape.heads).collect();
        let flat = Pool::auto().map(head_jobs, |idx| {
            let mut rng = Prng::derived(seed ^ 0xD15711, idx as u64);
            random_modal(&mut rng, d_state)
        });
        let mut modal: Vec<LayerModal> = Vec::with_capacity(shape.n_layer);
        let mut it = flat.into_iter();
        for _ in 0..shape.n_layer {
            let heads: Vec<ModalSsm> =
                (0..shape.heads).map(|_| it.next().expect("head modal")).collect();
            modal.push(LayerModal::from_heads(&heads, shape.d_model, d_state));
        }
        let d = shape.d_model;
        let tail = shape.short_kw - 1;
        RecurrentEngine {
            bb,
            modal,
            d_state,
            batch,
            x_re: vec![vec![0.0; shape.n_layer * d * d_state]; batch],
            x_im: vec![vec![0.0; shape.n_layer * d * d_state]; batch],
            sc: vec![vec![0.0; shape.n_layer * 3 * d * tail]; batch],
            sc_pos: vec![0; batch],
            last: vec![0; batch],
            scratch: (0..batch).map(|_| RowScratch::new(shape)).collect(),
            profiled: vec![false; batch],
        }
    }

    /// Mark one row (not) profiled.  Turning profiling on clears any
    /// stale aggregates so the next [`RecurrentEngine::take_row_stage_times`]
    /// covers exactly this request's tokens.
    pub fn set_row_profiling(&mut self, b: usize, on: bool) {
        if on && !self.profiled[b] {
            self.scratch[b].times = StageTimes::default();
        }
        self.profiled[b] = on;
    }

    /// Drain one row's per-stage profiling aggregates (zeroing them).
    pub fn take_row_stage_times(&mut self, b: usize) -> StageTimes {
        std::mem::take(&mut self.scratch[b].times)
    }

    /// Zero the generation state of one batch row (slot recycling).
    pub fn reset_row(&mut self, b: usize) {
        reset_row_state(
            &mut self.x_re[b],
            &mut self.x_im[b],
            &mut self.sc[b],
            &mut self.sc_pos[b],
        );
        self.last[b] = 0;
    }

    /// Prefill a single batch row with a prompt; returns the first greedy
    /// token. Rows are independent — this is the continuous-batching hook.
    pub fn prefill_row(&mut self, b: usize, prompt: &[i32]) -> i32 {
        let mut wanted: Vec<Option<&[i32]>> = vec![None; self.batch];
        wanted[b] = Some(prompt);
        self.prefill_wanted(&wanted)[0].1
    }

    /// Prefill several (slot, prompt) jobs, fanning the independent rows out
    /// over [`Pool`] workers — the coordinator's batched-prefill hot path.
    /// Returns (slot, first greedy token) pairs in ascending slot order.
    pub fn prefill_rows(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        let mut wanted: Vec<Option<&[i32]>> = vec![None; self.batch];
        for (slot, prompt) in jobs {
            wanted[*slot] = Some(prompt.as_slice());
        }
        self.prefill_wanted(&wanted)
    }

    /// Shared pooled prefill core: rows with a `Some(prompt)` entry are
    /// reset and consumed in parallel (each row owns disjoint state).
    fn prefill_wanted(&mut self, wanted: &[Option<&[i32]>]) -> Vec<(usize, i32)> {
        self.run_wanted(wanted, true)
    }

    /// Feed several (slot, tokens) jobs *without* resetting the rows,
    /// fanned out over the pool — the coordinator's batched session-resume
    /// hot path (same per-row math as [`RecurrentEngine::feed_row`]).
    pub fn feed_rows(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        let mut wanted: Vec<Option<&[i32]>> = vec![None; self.batch];
        for (slot, tokens) in jobs {
            wanted[*slot] = Some(tokens.as_slice());
        }
        self.run_wanted(&wanted, false)
    }

    /// Pooled multi-row token ingestion; `reset` distinguishes prefill
    /// (fresh rows) from session resume (continue from restored state).
    fn run_wanted(&mut self, wanted: &[Option<&[i32]>], reset: bool) -> Vec<(usize, i32)> {
        let Self { bb, modal, x_re, x_im, sc, sc_pos, d_state, last, scratch, profiled, .. } =
            self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let ds = *d_state;
        let bb = &*bb;
        let modal = &modal[..];
        let profiled = &profiled[..];
        let rows: Vec<_> = x_re
            .iter_mut()
            .zip(x_im.iter_mut())
            .zip(sc.iter_mut())
            .zip(sc_pos.iter_mut())
            .zip(last.iter_mut())
            .zip(scratch.iter_mut())
            .enumerate()
            .filter_map(|(b, (((((xr, xi), sc_b), pos), last_b), scr))| {
                wanted[b].map(|prompt| (b, xr, xi, sc_b, pos, last_b, scr, prompt))
            })
            .collect();
        Pool::auto().map(rows, |(b, xr, xi, sc_b, pos, last_b, scr, prompt)| {
            if reset {
                reset_row_state(xr, xi, sc_b, pos);
            }
            let fallback = if reset { 0 } else { *last_b };
            let next = consume_row(
                bb,
                modal,
                d,
                kw,
                ds,
                sc_b,
                pos,
                xr,
                xi,
                scr,
                prompt,
                fallback,
                profiled[b],
            );
            *last_b = next;
            (b, next)
        })
    }

    /// One pooled decode step over the given rows (each feeds back its own
    /// pending `last` token); returns (row, next token) pairs in the
    /// caller's `active` order.  Rows are independent, so the fan-out is
    /// bit-identical to stepping each row serially — asserted by
    /// `pooled_decode_matches_serial_across_partial_active_sets`.  `active`
    /// entries must be unique.
    pub fn decode_rows(&mut self, active: &[usize]) -> Vec<(usize, i32)> {
        let mut mask = vec![false; self.batch];
        for &s in active {
            mask[s] = true;
        }
        let Self { bb, modal, x_re, x_im, sc, sc_pos, d_state, last, scratch, profiled, .. } =
            self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let ds = *d_state;
        let bb = &*bb;
        let modal = &modal[..];
        let profiled = &profiled[..];
        let rows: Vec<_> = x_re
            .iter_mut()
            .zip(x_im.iter_mut())
            .zip(sc.iter_mut())
            .zip(sc_pos.iter_mut())
            .zip(last.iter_mut())
            .zip(scratch.iter_mut())
            .enumerate()
            .filter_map(|(b, (((((xr, xi), sc_b), pos), last_b), scr))| {
                if mask[b] {
                    Some((b, xr, xi, sc_b, pos, last_b, scr))
                } else {
                    None
                }
            })
            .collect();
        let stepped = Pool::auto().map(rows, |(b, xr, xi, sc_b, pos, last_b, scr)| {
            let tok = [*last_b];
            let next = consume_row(
                bb,
                modal,
                d,
                kw,
                ds,
                sc_b,
                pos,
                xr,
                xi,
                scr,
                &tok,
                *last_b,
                profiled[b],
            );
            *last_b = next;
            (b, next)
        });
        // report in the caller's order (the fan-out ran in slot order)
        let mut by_slot = vec![0i32; mask.len()];
        for (b, t) in &stepped {
            by_slot[*b] = *t;
        }
        active.iter().map(|&s| (s, by_slot[s])).collect()
    }

    /// One decode step for a single row.
    pub fn decode_row(&mut self, b: usize) -> i32 {
        let tok = self.last[b];
        self.feed_row(b, &[tok])
    }

    /// Feed tokens through one row *without* resetting it — the session
    /// resume hook.  Starting from a restored snapshot, feeding the
    /// snapshot's pending `last_token` followed by the new turn's tokens is
    /// arithmetically identical to prefilling the whole transcript from
    /// scratch (same per-token op sequence), which is what makes resumed
    /// sessions bit-exact.  Returns the greedy token after the last fed
    /// token (the row's `last` if `tokens` is empty).
    pub fn feed_row(&mut self, b: usize, tokens: &[i32]) -> i32 {
        let Self { bb, modal, x_re, x_im, sc, sc_pos, d_state, last, scratch, profiled, .. } =
            self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let next = consume_row(
            bb,
            modal,
            d,
            kw,
            *d_state,
            &mut sc[b],
            &mut sc_pos[b],
            &mut x_re[b],
            &mut x_im[b],
            &mut scratch[b],
            tokens,
            last[b],
            profiled[b],
        );
        last[b] = next;
        next
    }

    /// Extract one row's full per-layer SSM + short-conv state as a
    /// versioned [`SessionState`] blob (O(d) bytes, independent of how many
    /// tokens the row has consumed — Lemma 2.2 is what makes sessions
    /// cheap).  The short-conv plane is linearized to oldest-first order,
    /// so the blob bytes do not depend on the row's circular cursor.
    pub fn snapshot_row(&self, b: usize) -> SessionState {
        let mut st = SessionState::new(STATE_TAG, self.last[b]);
        st.push_plane("x_re", self.x_re[b].clone());
        st.push_plane("x_im", self.x_im[b].clone());
        st.push_plane("sc", self.linearized_sc(b));
        st
    }

    /// The `sc` plane in blob (oldest-first) order, independent of the
    /// physical cursor position.
    fn linearized_sc(&self, b: usize) -> Vec<f32> {
        let tail = self.bb.shape.short_kw - 1;
        let row = &self.sc[b];
        if tail == 0 {
            return Vec::new();
        }
        let pos = self.sc_pos[b];
        let mut out = Vec::with_capacity(row.len());
        for win in row.chunks_exact(tail) {
            for j in 0..tail {
                let idx = pos + j;
                out.push(win[if idx >= tail { idx - tail } else { idx }]);
            }
        }
        out
    }

    /// Reinstall a snapshot into one row, validating engine tag and shape.
    /// The blob's oldest-first `sc` plane is installed at cursor 0 (where
    /// physical order equals logical order).
    pub fn restore_row(&mut self, b: usize, st: &SessionState) -> Result<(), SessionError> {
        st.check_engine(STATE_TAG)?;
        let shape = &self.bb.shape;
        let x_len = shape.n_layer * shape.d_model * self.d_state;
        let sc_len = shape.n_layer * 3 * shape.d_model * (shape.short_kw - 1);
        let x_re = st.plane_checked("x_re", x_len)?;
        let x_im = st.plane_checked("x_im", x_len)?;
        let sc = st.plane_checked("sc", sc_len)?;
        self.x_re[b].copy_from_slice(x_re);
        self.x_im[b].copy_from_slice(x_im);
        self.sc[b].copy_from_slice(sc);
        self.sc_pos[b] = 0;
        self.last[b] = st.last_token;
        Ok(())
    }

    /// Bytes of generation state one slot costs.
    pub fn bytes_per_row(&self) -> u64 {
        self.state_bytes() / self.batch as u64
    }

    /// Replace the synthetic modal systems of one layer (distillery output).
    pub fn set_layer_modal(&mut self, layer: usize, systems: &[ModalSsm]) {
        assert_eq!(systems.len(), self.bb.shape.heads);
        self.modal[layer] =
            LayerModal::from_heads(systems, self.bb.shape.d_model, self.d_state);
    }
}

/// Zero one row's per-layer generation buffers — the single reset site
/// shared by [`RecurrentEngine::reset_row`] and the pooled prefill (add any
/// new per-row state buffer here so slot recycling can't go stale).
fn reset_row_state(xr: &mut [f32], xi: &mut [f32], sc: &mut [f32], pos: &mut usize) {
    xr.fill(0.0);
    xi.fill(0.0);
    sc.fill(0.0);
    *pos = 0;
}

/// Feed `tokens` through one row's recurrence (no reset) and return the
/// greedy token after the last one (`fallback` when `tokens` is empty).
/// The single per-token path shared by prefill, decode and session resume —
/// sharing it is what guarantees the three produce identical arithmetic.
/// `profile` routes the token through the timed twin of the same code
/// (per-stage wall clocks into the row's [`StageTimes`]); unprofiled
/// rows pay exactly this one branch per token.
#[allow(clippy::too_many_arguments)]
fn consume_row(
    bb: &Backbone,
    modal: &[LayerModal],
    d: usize,
    kw: usize,
    ds: usize,
    sc_b: &mut [f32],
    sc_pos: &mut usize,
    xr_b: &mut [f32],
    xi_b: &mut [f32],
    scratch: &mut RowScratch,
    tokens: &[i32],
    fallback: i32,
    profile: bool,
) -> i32 {
    if tokens.is_empty() {
        return fallback;
    }
    let tail = kw - 1;
    let x_plane = d * ds; // per-layer SSM plane length
    let sc_plane = 3 * d * tail; // per-layer short-conv length
    for &tok in tokens {
        let pos = *sc_pos;
        let RowScratch { bb: bb_scr, qkv_c, times } = scratch;
        if !profile {
            bb.decode_one(tok, bb_scr, |li, qkv, out| {
                mix_one(
                    d,
                    kw,
                    ds,
                    &modal[li],
                    &mut sc_b[li * sc_plane..(li + 1) * sc_plane],
                    pos,
                    &mut xr_b[li * x_plane..(li + 1) * x_plane],
                    &mut xi_b[li * x_plane..(li + 1) * x_plane],
                    qkv,
                    qkv_c,
                    out,
                );
            });
        } else {
            let (mut sc_ns, mut sweep_ns) = (0u64, 0u64);
            bb.decode_one_timed(
                tok,
                bb_scr,
                |li, qkv, out| {
                    mix_one_timed(
                        d,
                        kw,
                        ds,
                        &modal[li],
                        &mut sc_b[li * sc_plane..(li + 1) * sc_plane],
                        pos,
                        &mut xr_b[li * x_plane..(li + 1) * x_plane],
                        &mut xi_b[li * x_plane..(li + 1) * x_plane],
                        qkv,
                        qkv_c,
                        out,
                        &mut sc_ns,
                        &mut sweep_ns,
                    );
                },
                times,
            );
            times.short_conv_ns += sc_ns;
            times.modal_sweep_ns += sweep_ns;
        }
        if tail > 0 {
            *sc_pos = (pos + 1) % tail;
        }
    }
    bb.greedy(&scratch.bb.logits)
}

/// Fused short-conv + gated SSM mixer for one token of one layer of one
/// row, allocation-free: `qkv_c` is the row's short-conv scratch and `out`
/// the backbone's mixer slot.  `pos` is the row's circular cursor into each
/// channel's `kw-1`-slot window of `buf` (see `RecurrentEngine::sc_pos`).
/// Free function so the backbone (&) and generation state (&mut) borrows
/// stay disjoint.
#[allow(clippy::too_many_arguments)]
fn mix_one(
    d: usize,
    kw: usize,
    ds: usize,
    modal: &LayerModal,
    buf: &mut [f32],
    pos: usize,
    xr: &mut [f32],
    xi: &mut [f32],
    qkv: &[f32],
    qkv_c: &mut [f32],
    out: &mut [f32],
) {
    short_conv_one(d, kw, buf, pos, qkv, qkv_c);
    sweep_one(d, ds, modal, xr, xi, qkv_c, out);
}

/// [`mix_one`] with the short-conv / modal-sweep split wall-clocked into
/// the caller's accumulators — the sampled-profiling twin.  Both paths
/// call the *same* two inlined stage helpers, so a profiled token's
/// arithmetic is bit-identical to an unprofiled one's.
#[allow(clippy::too_many_arguments)]
fn mix_one_timed(
    d: usize,
    kw: usize,
    ds: usize,
    modal: &LayerModal,
    buf: &mut [f32],
    pos: usize,
    xr: &mut [f32],
    xi: &mut [f32],
    qkv: &[f32],
    qkv_c: &mut [f32],
    out: &mut [f32],
    sc_ns: &mut u64,
    sweep_ns: &mut u64,
) {
    let t0 = Instant::now();
    short_conv_one(d, kw, buf, pos, qkv, qkv_c);
    *sc_ns += t0.elapsed().as_nanos() as u64;
    let t0 = Instant::now();
    sweep_one(d, ds, modal, xr, xi, qkv_c, out);
    *sweep_ns += t0.elapsed().as_nanos() as u64;
}

/// Short conv against the circular window: taps SHORT_TAPS[..kw], the
/// last weighting the current input, then overwrite the oldest slot
/// (the caller advances the cursor once per token).
#[inline(always)]
fn short_conv_one(d: usize, kw: usize, buf: &mut [f32], pos: usize, qkv: &[f32], qkv_c: &mut [f32]) {
    let tail = kw - 1;
    let cur = SHORT_TAPS[tail];
    if tail == 0 {
        for (o, &x) in qkv_c.iter_mut().zip(qkv) {
            *o = cur * x;
        }
    } else {
        let taps = &SHORT_TAPS[..tail];
        for c in 0..3 * d {
            let win = &mut buf[c * tail..(c + 1) * tail];
            let mut acc = cur * qkv[c];
            for (j, &w) in taps.iter().enumerate() {
                let idx = pos + j;
                acc += w * win[if idx >= tail { idx - tail } else { idx }];
            }
            qkv_c[c] = acc;
            win[pos] = qkv[c];
        }
    }
}

/// Gated SSM update: one contiguous [D, d] sweep over the interleaved
/// modal plane (no per-channel head lookup), dispatched through the
/// lane-structured / SIMD kernel — see engine::modal_sweep.
#[inline(always)]
fn sweep_one(
    d: usize,
    ds: usize,
    modal: &LayerModal,
    xr: &mut [f32],
    xi: &mut [f32],
    qkv_c: &mut [f32],
    out: &mut [f32],
) {
    let (q, rest) = qkv_c.split_at(d);
    let (k, v) = rest.split_at(d);
    for c in 0..d {
        let u = k[c] * v[c];
        let base = c * ds;
        let acc = super::modal_sweep::sweep(
            &modal.plane[base * 4..(base + ds) * 4],
            modal.h0[c],
            u,
            &mut xr[base..base + ds],
            &mut xi[base..base + ds],
        );
        out[c] = q[c] * acc;
    }
}

fn random_modal(rng: &mut Prng, d: usize) -> ModalSsm {
    let pairs: Vec<(C64, C64)> = (0..d / 2)
        .map(|_| {
            (
                C64::polar(rng.range(0.5, 0.95), rng.range(0.1, 2.9)),
                C64::new(rng.normal() * 0.2, rng.normal() * 0.2),
            )
        })
        .collect();
    ModalSsm::from_conjugate_pairs(&pairs, rng.normal() * 0.1)
}

impl Engine for RecurrentEngine {
    fn name(&self) -> &'static str {
        "laughing-hyena"
    }

    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Vec<i32> {
        assert_eq!(prompts.len(), self.batch);
        // consume every prompt through the recurrence (O(T d) state init;
        // the FFT variant is benchmarked at the filter level), with the
        // independent rows fanned out over the pool
        let wanted: Vec<Option<&[i32]>> =
            prompts.iter().map(|p| Some(p.as_slice())).collect();
        let firsts = self.prefill_wanted(&wanted);
        let mut out = vec![0i32; prompts.len()];
        for (slot, tok) in firsts {
            out[slot] = tok;
        }
        out
    }

    fn decode(&mut self) -> Vec<i32> {
        let all: Vec<usize> = (0..self.batch).collect();
        self.decode_rows(&all).into_iter().map(|(_, t)| t).collect()
    }

    fn state_bytes(&self) -> u64 {
        let shape = &self.bb.shape;
        let per_seq = shape.n_layer
            * (2 * shape.d_model * self.d_state // re+im state
                + 3 * shape.d_model * (shape.short_kw - 1));
        (self.batch * per_seq * 4) as u64
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_generation;
    use crate::util::prop::check;

    #[test]
    fn generates_tokens_in_vocab() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 2, 7);
        let prompts = vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]];
        let first = eng.prefill(&prompts);
        assert_eq!(first.len(), 2);
        for _ in 0..4 {
            let toks = eng.decode();
            assert!(toks.iter().all(|&t| (t as usize) < shape.vocab));
        }
    }

    #[test]
    fn state_is_constant_during_generation() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 1, 7);
        let r = run_generation(&mut eng, &[vec![1; 16]], 8);
        let expected = eng.state_bytes();
        assert_eq!(r.peak_state_bytes, expected, "O(d) memory must not grow");
    }

    #[test]
    fn deterministic_given_seed() {
        let shape = LmShape::bench("nano").unwrap();
        let mut e1 = RecurrentEngine::new(&shape, 1, 3);
        let mut e2 = RecurrentEngine::new(&shape, 1, 3);
        let p = vec![vec![2, 4, 6]];
        assert_eq!(e1.prefill(&p), e2.prefill(&p));
        assert_eq!(e1.decode(), e2.decode());
    }

    #[test]
    fn snapshot_restore_resume_is_bit_identical() {
        // generate, snapshot mid-stream, keep generating on A; restore the
        // snapshot into a *different* engine row and replay — every token
        // must match bit-for-bit.
        let shape = LmShape::bench("nano").unwrap();
        let mut a = RecurrentEngine::new(&shape, 2, 13);
        a.prefill_row(0, &[3, 1, 4, 1, 5]);
        for _ in 0..3 {
            a.decode_row(0);
        }
        let snap = a.snapshot_row(0);
        let cont_a: Vec<i32> = (0..6).map(|_| a.decode_row(0)).collect();
        let mut b = RecurrentEngine::new(&shape, 2, 13);
        b.restore_row(1, &snap).unwrap();
        let cont_b: Vec<i32> = (0..6).map(|_| b.decode_row(1)).collect();
        assert_eq!(cont_a, cont_b);
    }

    #[test]
    fn feed_without_reset_matches_fresh_prefill_of_transcript() {
        // resume semantics: state(prefix) + feed(rest) == prefill(prefix ++ rest)
        let shape = LmShape::bench("nano").unwrap();
        let prefix = vec![7, 8, 9, 2];
        let rest = vec![4, 4, 1];
        let mut split = RecurrentEngine::new(&shape, 1, 5);
        split.prefill_row(0, &prefix);
        let first_split = split.feed_row(0, &rest);
        let mut whole = RecurrentEngine::new(&shape, 1, 5);
        let mut full = prefix.clone();
        full.extend_from_slice(&rest);
        let first_whole = whole.prefill_row(0, &full);
        assert_eq!(first_split, first_whole);
        for _ in 0..5 {
            assert_eq!(split.decode_row(0), whole.decode_row(0));
        }
    }

    #[test]
    fn pooled_feed_rows_matches_row_by_row() {
        // the batched session-resume path must agree exactly with feeding
        // each row on its own
        let shape = LmShape::bench("nano").unwrap();
        let mut pooled = RecurrentEngine::new(&shape, 3, 21);
        let mut serial = RecurrentEngine::new(&shape, 3, 21);
        for b in 0..3 {
            pooled.prefill_row(b, &[1 + b as i32, 5, 9]);
            serial.prefill_row(b, &[1 + b as i32, 5, 9]);
        }
        let jobs: Vec<(usize, Vec<i32>)> =
            (0..3).map(|b| (b, vec![2 + b as i32, 4])).collect();
        let batch = pooled.feed_rows(&jobs);
        let mut row_by_row = vec![];
        for (b, toks) in &jobs {
            row_by_row.push((*b, serial.feed_row(*b, toks)));
        }
        assert_eq!(batch, row_by_row);
        for _ in 0..3 {
            assert_eq!(pooled.decode(), serial.decode());
        }
    }

    #[test]
    fn profiled_rows_are_bit_identical_and_attribute_stages() {
        // the sampled-profiling twin runs the same stage helpers in the
        // same order — prefill + pooled decode must match an unprofiled
        // engine token-for-token, while the profiled row accumulates
        // per-stage attribution and the unprofiled row stays at zero
        let shape = LmShape::bench("nano").unwrap();
        let mut plain = RecurrentEngine::new(&shape, 2, 11);
        let mut prof = RecurrentEngine::new(&shape, 2, 11);
        prof.set_row_profiling(0, true);
        let prompts = vec![vec![1, 2, 3], vec![4, 5, 6, 7]];
        assert_eq!(plain.prefill(&prompts), prof.prefill(&prompts));
        for _ in 0..4 {
            assert_eq!(plain.decode(), prof.decode());
        }
        // row 0: 3 prefill tokens + 4 decode steps, every stage timed
        let t = prof.take_row_stage_times(0);
        assert_eq!(t.tokens, 7);
        assert!(t.total_ns() > 0);
        assert!(t.qkv_ns > 0 && t.mlp_ns > 0 && t.lm_head_ns > 0);
        assert!(t.short_conv_ns > 0 && t.modal_sweep_ns > 0);
        // take drains: a second take is zero
        assert_eq!(prof.take_row_stage_times(0), StageTimes::default());
        // the unprofiled neighbor recorded nothing
        assert_eq!(prof.take_row_stage_times(1), StageTimes::default());
        // re-enabling clears stale aggregates, feed_row is covered too
        prof.set_row_profiling(1, true);
        assert_eq!(plain.feed_row(1, &[9, 9]), prof.feed_row(1, &[9, 9]));
        assert_eq!(prof.take_row_stage_times(1).tokens, 2);
    }

    #[test]
    fn restore_rejects_foreign_and_misshapen_blobs() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 1, 5);
        let mut snap = eng.snapshot_row(0);
        snap.engine = "transformer".into();
        assert!(eng.restore_row(0, &snap).is_err());
        let mut snap2 = eng.snapshot_row(0);
        snap2.planes[0].data.pop();
        assert!(eng.restore_row(0, &snap2).is_err());
    }

    #[test]
    fn pooled_prefill_matches_row_by_row() {
        // the pooled batch prefill must agree exactly with prefilling each
        // row on its own (rows are independent by construction)
        let shape = LmShape::bench("nano").unwrap();
        let prompts = vec![vec![1, 2, 3, 4], vec![9, 8, 7], vec![5; 6], vec![2, 2]];
        let mut pooled = RecurrentEngine::new(&shape, 4, 21);
        let mut serial = RecurrentEngine::new(&shape, 4, 21);
        let batch_first = pooled.prefill(&prompts);
        let mut row_first = Vec::new();
        for (b, p) in prompts.iter().enumerate() {
            row_first.push(serial.prefill_row(b, p));
        }
        assert_eq!(batch_first, row_first);
        for _ in 0..4 {
            assert_eq!(pooled.decode(), serial.decode());
        }
    }

    #[test]
    fn pooled_decode_matches_serial_across_partial_active_sets() {
        // the fused + pooled decode step must agree bit-for-bit with
        // stepping each row on its own, for full and partial active sets
        let shape = LmShape::bench("nano").unwrap();
        let mut pooled = RecurrentEngine::new(&shape, 4, 31);
        let mut serial = RecurrentEngine::new(&shape, 4, 31);
        for b in 0..4 {
            let p = vec![1 + b as i32, 9, 3, 7];
            pooled.prefill_row(b, &p);
            serial.prefill_row(b, &p);
        }
        let sets: [&[usize]; 5] = [&[0, 1, 2, 3], &[2], &[1, 3], &[0, 2, 3], &[3, 0]];
        for active in sets {
            let batch = pooled.decode_rows(active);
            let one: Vec<(usize, i32)> =
                active.iter().map(|&s| (s, serial.decode_row(s))).collect();
            assert_eq!(batch, one, "active set {active:?}");
        }
    }

    #[test]
    fn fused_kernel_matches_modal_ssm_step_reference() {
        // the fused per-channel update must (a) agree bit-for-bit with the
        // canonical lane-ordered kernel whatever `sweep` dispatches to
        // (scalar or AVX2 — see engine::modal_sweep for the exhaustive
        // shape sweep), (b) advance the *state* bit-identically to a
        // scalar f32 transcription of ModalSsm::step (the state update is
        // order-free), and (c) track the f64 ModalSsm::step reference on
        // the same (f32-cast) poles/residues to f32 accumulation accuracy.
        // The output contraction is compared to the sequential
        // transcription with a reassociation tolerance: its lane-tree
        // order (chosen so the kernel vectorizes without changing bits
        // between scalar and SIMD) reorders the sum.
        use crate::engine::modal_sweep;
        check("fused SSM channel == ModalSsm::step", 16, |rng| {
            let ds = 2 * (1 + rng.below(8)); // 2..=16: sub-lane and full-lane
            let sys = random_modal(rng, ds);
            // interleaved plane, f32-cast exactly like LayerModal::from_heads
            let mut plane = Vec::with_capacity(ds * 4);
            for n in 0..ds {
                plane.push(sys.poles[n].re as f32);
                plane.push(sys.poles[n].im as f32);
                plane.push(sys.residues[n].re as f32);
                plane.push(sys.residues[n].im as f32);
            }
            let h0 = sys.h0 as f32;
            // f64 reference system over the f32-cast parameters
            let sys32 = ModalSsm::new(
                sys.poles.iter().map(|p| C64::new(p.re as f32 as f64, p.im as f32 as f64)).collect(),
                sys.residues.iter().map(|r| C64::new(r.re as f32 as f64, r.im as f32 as f64)).collect(),
                h0 as f64,
            );
            let mut st = sys32.zero_state();
            let mut xr = vec![0.0f32; ds];
            let mut xi = vec![0.0f32; ds];
            let (mut cxr, mut cxi) = (vec![0.0f32; ds], vec![0.0f32; ds]);
            let (mut rxr, mut rxi) = (vec![0.0f32; ds], vec![0.0f32; ds]);
            for t in 0..24 {
                let u = rng.normal() as f32;
                let got = modal_sweep::sweep(&plane, h0, u, &mut xr, &mut xi);
                let canon = modal_sweep::ssm_channel_step(&plane, h0, u, &mut cxr, &mut cxi);
                if got.to_bits() != canon.to_bits() {
                    return Err(format!("step {t}: dispatch {got} != canonical {canon}"));
                }
                // scalar f32 transcription of ModalSsm::step, sequential order
                let mut seq = h0 * u;
                for n in 0..ds {
                    let (re, im) = (rxr[n], rxi[n]);
                    seq += plane[n * 4 + 2] * re - plane[n * 4 + 3] * im;
                    rxr[n] = plane[n * 4] * re - plane[n * 4 + 1] * im + u;
                    rxi[n] = plane[n * 4] * im + plane[n * 4 + 1] * re;
                }
                for n in 0..ds {
                    if xr[n].to_bits() != rxr[n].to_bits()
                        || xi[n].to_bits() != rxi[n].to_bits()
                        || cxr[n].to_bits() != rxr[n].to_bits()
                        || cxi[n].to_bits() != rxi[n].to_bits()
                    {
                        return Err(format!("step {t}: state bits diverged at mode {n}"));
                    }
                }
                // sequential vs lane-tree order: pure reassociation noise
                let rtol = 1e-4 * (1.0 + seq.abs());
                if (got - seq).abs() > rtol {
                    return Err(format!(
                        "step {t}: fused {got} vs sequential {seq} (tol {rtol:.3e})"
                    ));
                }
                let want64 = sys32.step(&mut st, u as f64);
                // f32 state rounding compounds through the recurrence;
                // 1e-3 is ~10x the worst accumulated drift and far below
                // any formula-level mistake
                let tol = 1e-3 * (1.0 + want64.abs());
                if (got as f64 - want64).abs() > tol {
                    return Err(format!(
                        "step {t}: fused {got} vs f64 reference {want64} (tol {tol:.3e})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn snapshot_is_cursor_invariant() {
        // restoring a blob normalizes the circular cursor to 0;
        // re-snapshotting must reproduce identical plane bytes even though
        // the source row's cursor was mid-cycle
        let shape = LmShape::bench("nano").unwrap();
        let mut a = RecurrentEngine::new(&shape, 1, 9);
        a.prefill_row(0, &[5, 4, 3]); // 3 tokens -> cursor mid-window
        let snap = a.snapshot_row(0);
        let mut b = RecurrentEngine::new(&shape, 1, 9);
        b.restore_row(0, &snap).unwrap();
        let snap2 = b.snapshot_row(0);
        assert_eq!(snap.planes, snap2.planes);
        assert_eq!(snap.last_token, snap2.last_token);
    }

    #[test]
    fn snapshot_roundtrips_through_checkpoint_serialization() {
        // the PR-2 blob path end to end: snapshot -> checkpoint encode ->
        // decode -> restore must continue bit-identically
        let shape = LmShape::bench("nano").unwrap();
        let mut a = RecurrentEngine::new(&shape, 1, 17);
        a.prefill_row(0, &[6, 1, 8, 0, 3]);
        let snap = a.snapshot_row(0);
        let back = SessionState::from_checkpoint(&snap.to_checkpoint()).unwrap();
        let cont_a: Vec<i32> = (0..5).map(|_| a.decode_row(0)).collect();
        let mut b = RecurrentEngine::new(&shape, 1, 17);
        b.restore_row(0, &back).unwrap();
        let cont_b: Vec<i32> = (0..5).map(|_| b.decode_row(0)).collect();
        assert_eq!(cont_a, cont_b);
    }

    #[test]
    fn short_kw_one_runs_without_short_conv() {
        // kw = 1 is the no-short-conv configuration: zero-length windows,
        // empty sc plane, and the full generate/snapshot/resume cycle works
        let mut shape = LmShape::bench("nano").unwrap();
        shape.short_kw = 1;
        let mut eng = RecurrentEngine::new(&shape, 2, 7);
        let prompts = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let first = eng.prefill(&prompts);
        assert_eq!(first.len(), 2);
        for _ in 0..3 {
            let toks = eng.decode();
            assert!(toks.iter().all(|&t| (t as usize) < shape.vocab));
        }
        let snap = eng.snapshot_row(0);
        assert_eq!(snap.plane("sc").unwrap().len(), 0);
        let cont: Vec<i32> = (0..4).map(|_| eng.decode_row(0)).collect();
        let mut other = RecurrentEngine::new(&shape, 2, 7);
        other.restore_row(1, &snap).unwrap();
        let cont_b: Vec<i32> = (0..4).map(|_| other.decode_row(1)).collect();
        assert_eq!(cont, cont_b);
    }

    #[test]
    #[should_panic(expected = "invalid LmShape")]
    fn short_kw_zero_is_rejected_at_construction() {
        let mut shape = LmShape::bench("nano").unwrap();
        shape.short_kw = 0;
        let _ = RecurrentEngine::new(&shape, 1, 7);
    }
}
