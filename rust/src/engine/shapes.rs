//! Model shape presets: the paper's benchmark sizes (125M .. 6.7B, §D.4)
//! plus CPU-scale shapes the measured benches actually run.  Parameter
//! counts follow the GPT-style layout used throughout.

/// Longest short-conv kernel the engines' fixed tap table supports.
pub const MAX_SHORT_KW: usize = 3;

/// Fixed causal short-conv taps shared by the native engines (the AOT path
/// carries learned taps; the engines measure cost, not quality).  A kernel
/// of width `kw` uses the first `kw` entries: `SHORT_TAPS[kw - 1]` weights
/// the current input, `SHORT_TAPS[j]` the `j`-th oldest retained input.
pub const SHORT_TAPS: [f32; MAX_SHORT_KW] = [0.25, 0.35, 0.4];

/// Architecture shape (no weights).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LmShape {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    /// Long-conv heads (multihyena weight tying); d_model for plain hyena.
    pub heads: usize,
    pub attn_heads: usize,
    pub mlp_mult: usize,
    pub short_kw: usize,
    /// Distilled state dimension per channel.
    pub d_state: usize,
    /// Max filter length / training context.
    pub seq_len: usize,
}

impl LmShape {
    /// Paper benchmark sizes (§D.4 parameter scaling). `seq_len` set to the
    /// 2048 context these models use.
    pub fn paper(name: &str) -> Option<LmShape> {
        let mk = |name, d_model, n_layer| LmShape {
            name,
            vocab: 50_257,
            d_model,
            n_layer,
            heads: 8,
            attn_heads: d_model / 64,
            mlp_mult: 4,
            short_kw: 3,
            d_state: 16,
            seq_len: 2048,
        };
        match name {
            "125m" => Some(mk("125m", 768, 12)),
            "355m" => Some(mk("355m", 1024, 24)),
            "1.3b" => Some(mk("1.3b", 2048, 24)),
            "2.7b" => Some(mk("2.7b", 2560, 32)),
            "6.7b" => Some(mk("6.7b", 4096, 32)),
            _ => None,
        }
    }

    /// CPU-scale shapes for measured benches (same structure, smaller).
    pub fn bench(name: &str) -> Option<LmShape> {
        let mk = |name, vocab, d_model, n_layer, seq_len| LmShape {
            name,
            vocab,
            d_model,
            n_layer,
            heads: 8,
            attn_heads: 4,
            mlp_mult: 2,
            short_kw: 3,
            d_state: 16,
            seq_len,
        };
        match name {
            "nano" => Some(mk("nano", 256, 64, 2, 512)),
            "micro" => Some(mk("micro", 512, 128, 4, 1024)),
            "mini" => Some(mk("mini", 1024, 256, 6, 2048)),
            _ => None,
        }
    }

    /// Validate the structural invariants every engine relies on; returns
    /// a description of the first violation.  Called by
    /// [`super::backbone::Backbone::new`], so a bad shape fails loudly at
    /// engine construction instead of underflowing inside a kernel.
    ///
    /// `short_kw == 1` is the valid no-short-conv configuration (the
    /// rolling window has zero taps); `short_kw == 0` is meaningless and
    /// rejected, as is a width past the fixed tap table or a head count
    /// that does not divide `d_model`.
    pub fn validate(&self) -> Result<(), String> {
        if self.vocab == 0 || self.d_model == 0 || self.n_layer == 0 {
            return Err(format!(
                "{}: vocab, d_model and n_layer must all be nonzero",
                self.name
            ));
        }
        if self.short_kw == 0 {
            return Err(format!(
                "{}: short_kw must be >= 1 (1 means no short conv)",
                self.name
            ));
        }
        if self.short_kw > MAX_SHORT_KW {
            return Err(format!(
                "{}: short_kw {} exceeds the {MAX_SHORT_KW}-tap table",
                self.name, self.short_kw
            ));
        }
        if self.heads == 0 || self.d_model % self.heads != 0 {
            return Err(format!(
                "{}: heads {} must be nonzero and divide d_model {}",
                self.name, self.heads, self.d_model
            ));
        }
        if self.attn_heads == 0 || self.d_model % self.attn_heads != 0 {
            return Err(format!(
                "{}: attn_heads {} must be nonzero and divide d_model {}",
                self.name, self.attn_heads, self.d_model
            ));
        }
        if self.d_state == 0 {
            return Err(format!("{}: d_state must be nonzero", self.name));
        }
        Ok(())
    }

    /// Stable 64-bit fingerprint of every structural field (FNV-1a over
    /// the field values, not the name — two differently-named but
    /// structurally identical shapes interoperate).  The serve-layer
    /// handshake compares fingerprints so a session blob is never shipped
    /// toward an engine whose state layout cannot hold it.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(9 * 8);
        for v in [
            self.vocab,
            self.d_model,
            self.n_layer,
            self.heads,
            self.attn_heads,
            self.mlp_mult,
            self.short_kw,
            self.d_state,
            self.seq_len,
        ] {
            bytes.extend_from_slice(&(v as u64).to_le_bytes());
        }
        crate::util::bytes::fnv1a64(&bytes)
    }

    /// Approximate parameter count (embeddings + per-layer projections).
    pub fn params(&self) -> u64 {
        let d = self.d_model as u64;
        let per_layer = 3 * d * d // qkv
            + d * d // out
            + 2 * self.mlp_mult as u64 * d * d // mlp
            + 4 * d; // norms + biases (approx)
        self.vocab as u64 * d + self.n_layer as u64 * per_layer
    }

    /// FLOPs per generated token per sequence (dense projections dominate).
    pub fn flops_per_token(&self) -> u64 {
        2 * self.params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizes_roughly_match_names() {
        // within ~25% of the named parameter count
        for (name, want) in [("125m", 125e6), ("355m", 355e6), ("1.3b", 1.3e9), ("2.7b", 2.7e9)] {
            let s = LmShape::paper(name).unwrap();
            let p = s.params() as f64;
            assert!(
                (p / want - 1.0).abs() < 0.4,
                "{name}: {p:.2e} vs {want:.2e}"
            );
        }
    }

    #[test]
    fn bench_shapes_exist() {
        for n in ["nano", "micro", "mini"] {
            assert!(LmShape::bench(n).is_some());
        }
        assert!(LmShape::bench("huge").is_none());
    }

    #[test]
    fn presets_validate() {
        for n in ["125m", "355m", "1.3b", "2.7b", "6.7b"] {
            LmShape::paper(n).unwrap().validate().unwrap();
        }
        for n in ["nano", "micro", "mini"] {
            LmShape::bench(n).unwrap().validate().unwrap();
        }
    }

    #[test]
    fn fingerprint_separates_structures_not_names() {
        let nano = LmShape::bench("nano").unwrap();
        assert_eq!(nano.fingerprint(), LmShape::bench("nano").unwrap().fingerprint());
        // structurally identical shapes under different names agree
        let mut renamed = nano.clone();
        renamed.name = "other";
        assert_eq!(nano.fingerprint(), renamed.fingerprint());
        // any structural change must move the fingerprint
        for f in [
            |s: &mut LmShape| s.d_model *= 2,
            |s: &mut LmShape| s.n_layer += 1,
            |s: &mut LmShape| s.d_state += 1,
            |s: &mut LmShape| s.vocab += 1,
        ] {
            let mut changed = nano.clone();
            f(&mut changed);
            assert_ne!(nano.fingerprint(), changed.fingerprint());
        }
        assert_ne!(
            nano.fingerprint(),
            LmShape::bench("micro").unwrap().fingerprint()
        );
    }

    #[test]
    fn validate_rejects_degenerate_short_kw_and_heads() {
        let good = LmShape::bench("nano").unwrap();
        let mut kw1 = good.clone();
        kw1.short_kw = 1; // no-short-conv is a supported configuration
        kw1.validate().unwrap();
        let mut kw0 = good.clone();
        kw0.short_kw = 0;
        assert!(kw0.validate().unwrap_err().contains("short_kw"));
        let mut kw9 = good.clone();
        kw9.short_kw = MAX_SHORT_KW + 1;
        assert!(kw9.validate().unwrap_err().contains("tap table"));
        let mut heads = good.clone();
        heads.heads = 7; // does not divide d_model = 64
        assert!(heads.validate().unwrap_err().contains("heads"));
    }
}
