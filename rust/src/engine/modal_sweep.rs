//! The per-channel modal sweep — the innermost arithmetic of the decode
//! hot path (Prop. 3.3: one diagonal-SSM update + output contraction per
//! channel per token) — as a lane-structured kernel with an optional
//! explicit-SIMD path.
//!
//! # The canonical kernel
//!
//! [`ssm_channel_step`] consumes a channel's interleaved
//! `[lam_re, lam_im, r_re, r_im]` parameter plane (see
//! `recurrent::LayerModal`) and advances its `(x_re, x_im)` state in
//! place, returning `h0*u + Re⟨R, x⟩`.  The *state* update of mode `n`
//! touches only mode `n`, so its evaluation order is free; the output
//! *contraction* is a float sum, whose order is pinned so every
//! implementation produces identical bits:
//!
//! * modes are swept in groups of [`LANES`] = 8, each group accumulating
//!   element-wise into 8 **lane accumulators** (`lane j` takes modes
//!   `j, j+8, j+16, …` of the full groups);
//! * the ragged tail (`d_state % 8` trailing modes) accumulates
//!   sequentially into a separate scalar;
//! * the result is `(h0*u + tree(lanes)) + tail`, where `tree` is the
//!   fixed reduction `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` — exactly
//!   the shape an 8-wide register reduces in (extract high half, add,
//!   movehl, add, shuffle, add).
//!
//! Because the lane structure *is* the vector structure, LLVM can
//! auto-vectorize the stable-Rust kernel without reassociating any float
//! math, and the `core::arch` path below implements the same ops in the
//! same order — which is what makes the two **bit-identical**, property-
//! tested in this module and leaned on by every snapshot/resume
//! invariant upstream.
//!
//! # SIMD dispatch
//!
//! With `--features simd` on `x86_64`, [`sweep`] routes channels with at
//! least one full lane group through an AVX2 kernel
//! (`is_x86_feature_detected!` checked once, cached); everything else —
//! other architectures, builds without the feature, pre-AVX2 CPUs,
//! channels with `d_state < 8` — takes the scalar kernel.  No FMA is
//! ever used: contraction would change the bits.  [`force_scalar`] turns
//! the SIMD path off at runtime so the decode bench can measure the
//! delta inside one process.

/// Mode-group width of the canonical kernel (f32 lanes of one 256-bit
/// register); the contraction's lane accumulators have this many slots.
pub const LANES: usize = 8;

use std::sync::atomic::{AtomicBool, Ordering};

/// When set, [`sweep`] always takes the scalar kernel (bench hook for
/// measuring the SIMD delta; results are bit-identical either way).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Route [`sweep`] through the scalar kernel even when SIMD is available
/// (`on = true`), or restore auto dispatch (`on = false`).
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// True when [`sweep`] currently dispatches to the explicit-SIMD kernel:
/// the `simd` feature is compiled in, the CPU reports AVX2, and
/// [`force_scalar`] is off.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn simd_active() -> bool {
    !FORCE_SCALAR.load(Ordering::Relaxed) && have_avx2()
}

/// True when [`sweep`] currently dispatches to the explicit-SIMD kernel
/// (always false in builds without `--features simd` or off `x86_64`).
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn simd_active() -> bool {
    false
}

/// One-time cached `is_x86_feature_detected!("avx2")`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn have_avx2() -> bool {
    use std::sync::atomic::AtomicU8;
    // 0 = unknown, 1 = absent, 2 = present
    static DETECTED: AtomicU8 = AtomicU8::new(0);
    match DETECTED.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let ok = std::arch::is_x86_feature_detected!("avx2");
            DETECTED.store(if ok { 2 } else { 1 }, Ordering::Relaxed);
            ok
        }
    }
}

/// One channel's modal-SSM update against its interleaved
/// `[lam_re, lam_im, r_re, r_im]` plane slice, dispatching to the SIMD
/// kernel when available (see module docs): returns `h0*u + Re⟨R, x⟩`
/// and advances the state in place.  Bit-identical to
/// [`ssm_channel_step`] on every input, on every path.
#[inline]
pub fn sweep(plane: &[f32], h0: f32, u: f32, xr: &mut [f32], xi: &mut [f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if xr.len() >= LANES && simd_active() {
        // SAFETY: simd_active() verified AVX2 support at runtime.
        return unsafe { x86::sweep_avx2(plane, h0, u, xr, xi) };
    }
    ssm_channel_step(plane, h0, u, xr, xi)
}

/// The canonical scalar kernel (the f32 transcription of
/// [`crate::ssm::ModalSsm::step`], with the contraction in the pinned
/// lane order — see module docs).  Always available; written so LLVM can
/// auto-vectorize it without touching float semantics.
#[inline]
pub fn ssm_channel_step(plane: &[f32], h0: f32, u: f32, xr: &mut [f32], xi: &mut [f32]) -> f32 {
    let ds = xr.len();
    debug_assert_eq!(plane.len(), ds * 4);
    debug_assert_eq!(xi.len(), ds);
    let full = ds - ds % LANES;
    let mut lanes = [0.0f32; LANES];
    let mut g = 0;
    while g < full {
        for j in 0..LANES {
            let n = g + j;
            let m = &plane[n * 4..n * 4 + 4];
            let (re, im) = (xr[n], xi[n]);
            lanes[j] += m[2] * re - m[3] * im;
            xr[n] = m[0] * re - m[1] * im + u;
            xi[n] = m[0] * im + m[1] * re;
        }
        g += LANES;
    }
    let mut tail = 0.0f32;
    for n in full..ds {
        let m = &plane[n * 4..n * 4 + 4];
        let (re, im) = (xr[n], xi[n]);
        tail += m[2] * re - m[3] * im;
        xr[n] = m[0] * re - m[1] * im + u;
        xi[n] = m[0] * im + m[1] * re;
    }
    (h0 * u + lane_tree(&lanes)) + tail
}

/// The pinned reduction tree over the lane accumulators — exactly the op
/// sequence the AVX2 epilogue performs, so both paths add in the same
/// order.
#[inline]
fn lane_tree(l: &[f32; LANES]) -> f32 {
    let b = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
    (b[0] + b[2]) + (b[1] + b[3])
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::LANES;
    use core::arch::x86_64::*;

    /// AVX2 modal sweep: per 8-mode group, de-interleave the
    /// `[lam_re, lam_im, r_re, r_im]` quadruples with a two-level
    /// transpose (cross-lane 128-bit permutes, then the classic in-lane
    /// 4x4 unpack/shuffle), update both state registers, and accumulate
    /// the contraction into one 8-lane register.  Only `mul`/`add`/`sub`
    /// — never FMA — in the exact op order of
    /// [`super::ssm_channel_step`], ending in the same reduction tree
    /// and the same sequential scalar tail, so the two kernels are
    /// bit-identical on every input.
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sweep_avx2(
        plane: &[f32],
        h0: f32,
        u: f32,
        xr: &mut [f32],
        xi: &mut [f32],
    ) -> f32 {
        let ds = xr.len();
        debug_assert_eq!(plane.len(), ds * 4);
        debug_assert_eq!(xi.len(), ds);
        let full = ds - ds % LANES;
        let p = plane.as_ptr();
        let xrp = xr.as_mut_ptr();
        let xip = xi.as_mut_ptr();
        let uv = _mm256_set1_ps(u);
        let mut acc = _mm256_setzero_ps();
        let mut g = 0usize;
        while g < full {
            // 8 interleaved quadruples = 32 contiguous floats
            let q = p.add(g * 4);
            let v0 = _mm256_loadu_ps(q); //           modes g+0, g+1
            let v1 = _mm256_loadu_ps(q.add(8)); //    modes g+2, g+3
            let v2 = _mm256_loadu_ps(q.add(16)); //   modes g+4, g+5
            let v3 = _mm256_loadu_ps(q.add(24)); //   modes g+6, g+7
            // pair quad k with quad k+4 across the 128-bit halves ...
            let t0 = _mm256_permute2f128_ps::<0x20>(v0, v2); // quads 0 | 4
            let t1 = _mm256_permute2f128_ps::<0x31>(v0, v2); // quads 1 | 5
            let t2 = _mm256_permute2f128_ps::<0x20>(v1, v3); // quads 2 | 6
            let t3 = _mm256_permute2f128_ps::<0x31>(v1, v3); // quads 3 | 7
            // ... then transpose each half's 4x4 block in-lane
            let u0 = _mm256_unpacklo_ps(t0, t1); // lam_re01 lam_im01 | ..45
            let u1 = _mm256_unpackhi_ps(t0, t1); // r_re01   r_im01   | ..45
            let u2 = _mm256_unpacklo_ps(t2, t3); // lam_re23 lam_im23 | ..67
            let u3 = _mm256_unpackhi_ps(t2, t3); // r_re23   r_im23   | ..67
            let lam_re = _mm256_shuffle_ps::<0b01_00_01_00>(u0, u2);
            let lam_im = _mm256_shuffle_ps::<0b11_10_11_10>(u0, u2);
            let r_re = _mm256_shuffle_ps::<0b01_00_01_00>(u1, u3);
            let r_im = _mm256_shuffle_ps::<0b11_10_11_10>(u1, u3);
            let re = _mm256_loadu_ps(xrp.add(g));
            let im = _mm256_loadu_ps(xip.add(g));
            // lanes[j] += r_re*re - r_im*im
            acc = _mm256_add_ps(
                acc,
                _mm256_sub_ps(_mm256_mul_ps(r_re, re), _mm256_mul_ps(r_im, im)),
            );
            // x <- lam*x + u (complex multiply, real input injection)
            let nr = _mm256_add_ps(
                _mm256_sub_ps(_mm256_mul_ps(lam_re, re), _mm256_mul_ps(lam_im, im)),
                uv,
            );
            let ni = _mm256_add_ps(_mm256_mul_ps(lam_re, im), _mm256_mul_ps(lam_im, re));
            _mm256_storeu_ps(xrp.add(g), nr);
            _mm256_storeu_ps(xip.add(g), ni);
            g += LANES;
        }
        // the exact lane_tree reduction: halves, movehl, lane-1 shuffle
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps::<1>(acc);
        let b = _mm_add_ps(lo, hi); // [l0+l4, l1+l5, l2+l6, l3+l7]
        let c = _mm_add_ps(b, _mm_movehl_ps(b, b)); // [b0+b2, b1+b3, ..]
        let tree = _mm_cvtss_f32(_mm_add_ss(c, _mm_shuffle_ps::<0b01>(c, c)));
        // sequential scalar tail, same order as the canonical kernel
        let mut tail = 0.0f32;
        for n in full..ds {
            let m = &plane[n * 4..n * 4 + 4];
            let (re, im) = (xr[n], xi[n]);
            tail += m[2] * re - m[3] * im;
            xr[n] = m[0] * re - m[1] * im + u;
            xi[n] = m[0] * im + m[1] * re;
        }
        (h0 * u + tree) + tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use std::sync::Mutex;

    /// Tests that read *or* toggle the process-global [`force_scalar`]
    /// flag serialize here, so a concurrently running toggle test cannot
    /// silently strip the SIMD path out of the bit-identity property test
    /// (the harness runs tests on multiple threads).
    static DISPATCH_LOCK: Mutex<()> = Mutex::new(());

    /// Random (plane, h0) in the ranges the distillery produces: stable
    /// poles, O(1) residues.
    fn random_plane(rng: &mut crate::util::Prng, ds: usize) -> (Vec<f32>, f32) {
        let mut plane = Vec::with_capacity(ds * 4);
        for _ in 0..ds {
            let (r, th) = (rng.range(0.3, 0.99), rng.range(0.0, 6.28));
            plane.push((r * th.cos()) as f32);
            plane.push((r * th.sin()) as f32);
            plane.push(rng.normal() as f32);
            plane.push(rng.normal() as f32);
        }
        (plane, rng.normal() as f32)
    }

    #[test]
    fn dispatch_is_bit_identical_to_scalar_across_shapes() {
        // the tentpole invariant: whatever `sweep` dispatches to (AVX2
        // when built with --features simd on an AVX2 machine, scalar
        // otherwise) must match the canonical kernel bit for bit —
        // output AND state — including ragged tails (ds % 8 != 0) and
        // sub-lane shapes (ds < 8)
        let _dispatch = DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        check("sweep dispatch == scalar kernel", 64, |rng| {
            let ds = 1 + rng.below(21); // 1..=21 covers <8, =8, 16, ragged
            let (plane, h0) = random_plane(rng, ds);
            let mut xr_a = vec![0.0f32; ds];
            let mut xi_a = vec![0.0f32; ds];
            let mut xr_b = vec![0.0f32; ds];
            let mut xi_b = vec![0.0f32; ds];
            for t in 0..32 {
                let u = rng.normal() as f32;
                let got = sweep(&plane, h0, u, &mut xr_a, &mut xi_a);
                let want = ssm_channel_step(&plane, h0, u, &mut xr_b, &mut xi_b);
                if got.to_bits() != want.to_bits() {
                    return Err(format!(
                        "ds={ds} step {t}: sweep {got} != scalar {want} \
                         (simd_active={})",
                        simd_active()
                    ));
                }
                for n in 0..ds {
                    if xr_a[n].to_bits() != xr_b[n].to_bits()
                        || xi_a[n].to_bits() != xi_b[n].to_bits()
                    {
                        return Err(format!("ds={ds} step {t}: state bits at mode {n}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn force_scalar_roundtrips_and_keeps_bits() {
        // flipping the bench hook must not change a single bit
        let _dispatch = DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = crate::util::Prng::new(42);
        let ds = 16;
        let (plane, h0) = random_plane(&mut rng, ds);
        let (mut xr_a, mut xi_a) = (vec![0.0f32; ds], vec![0.0f32; ds]);
        let (mut xr_b, mut xi_b) = (vec![0.0f32; ds], vec![0.0f32; ds]);
        for _ in 0..16 {
            let u = rng.normal() as f32;
            force_scalar(false);
            let auto = sweep(&plane, h0, u, &mut xr_a, &mut xi_a);
            force_scalar(true);
            assert!(!simd_active(), "force_scalar must win the dispatch");
            let scal = sweep(&plane, h0, u, &mut xr_b, &mut xi_b);
            force_scalar(false);
            assert_eq!(auto.to_bits(), scal.to_bits());
        }
    }

    #[test]
    fn tail_is_sequential_and_lanes_are_strided() {
        // pin the contraction order contract itself: lane j owns modes
        // j, j+8, ... of the full groups; the tail sums sequentially;
        // the tree is ((l0+l4)+(l2+l6)) + ((l1+l7... see lane_tree)
        let ds = 11; // one full group + 3-mode tail
        let plane: Vec<f32> = (0..ds)
            .flat_map(|n| [0.0, 0.0, (n + 1) as f32, 0.0])
            .collect();
        let mut xr = vec![1.0f32; ds];
        let mut xi = vec![0.0f32; ds];
        let got = ssm_channel_step(&plane, 0.0, 0.0, &mut xr, &mut xi);
        // lanes j = 1..=8 (modes 0..8), tail = 9 + 10 + 11
        let l: Vec<f32> = (1..=8).map(|v| v as f32).collect();
        let b = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
        let want = ((b[0] + b[2]) + (b[1] + b[3])) + (9.0 + 10.0 + 11.0);
        assert_eq!(got.to_bits(), want.to_bits());
        // state picked up u = 0 through lam = 0: fully zeroed
        assert!(xr.iter().chain(xi.iter()).all(|v| *v == 0.0));
    }

    #[test]
    fn zero_modes_degenerates_to_h0_times_u() {
        let (mut xr, mut xi) = (Vec::new(), Vec::new());
        let got = ssm_channel_step(&[], 0.5, -2.0, &mut xr, &mut xi);
        assert_eq!(got, (0.5f32 * -2.0 + 0.0) + 0.0);
        assert_eq!(got.to_bits(), sweep(&[], 0.5, -2.0, &mut xr, &mut xi).to_bits());
    }
}
