//! KV-cached Transformer engine (Lemma 2.3): O(t) attention per token with
//! an O(L) cache of two tensors per layer — the memory profile that caps
//! Transformer batch sizes in Figure 1.1.

use super::backbone::Backbone;
use super::shapes::LmShape;
use super::Engine;

pub struct TransformerEngine {
    bb: Backbone,
    batch: usize,
    /// K and V caches: [B][layer][t * D], growing per token.
    k_cache: Vec<Vec<Vec<f32>>>,
    v_cache: Vec<Vec<Vec<f32>>>,
    last: Vec<i32>,
}

impl TransformerEngine {
    pub fn new(shape: &LmShape, batch: usize, seed: u64) -> TransformerEngine {
        TransformerEngine {
            bb: Backbone::new(shape, seed),
            batch,
            k_cache: vec![vec![Vec::new(); shape.n_layer]; batch],
            v_cache: vec![vec![Vec::new(); shape.n_layer]; batch],
            last: vec![0; batch],
        }
    }
}

/// Multi-head causal attention over the cache for a single new position.
fn mix_attn(
    d: usize,
    nh: usize,
    kc: &mut Vec<f32>,
    vc: &mut Vec<f32>,
    qkv: &[f32],
) -> Vec<f32> {
    let hd = d / nh;
    let (q, rest) = qkv.split_at(d);
    let (k, v) = rest.split_at(d);
    kc.extend_from_slice(k);
    vc.extend_from_slice(v);
    let t = kc.len() / d;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut y = vec![0.0f32; d];
    let mut scores = vec![0.0f32; t];
    for h in 0..nh {
        let off = h * hd;
        // scores over the whole cache (O(t * hd))
        let mut max_s = f32::MIN;
        for j in 0..t {
            let mut s = 0.0f32;
            let krow = &kc[j * d + off..j * d + off + hd];
            for (a, b) in q[off..off + hd].iter().zip(krow) {
                s += a * b;
            }
            let s = s * scale;
            scores[j] = s;
            max_s = max_s.max(s);
        }
        let mut denom = 0.0f32;
        for s in scores.iter_mut().take(t) {
            *s = (*s - max_s).exp();
            denom += *s;
        }
        for j in 0..t {
            let w = scores[j] / denom;
            let vrow = &vc[j * d + off..j * d + off + hd];
            for (o, &b) in y[off..off + hd].iter_mut().zip(vrow) {
                *o += w * b;
            }
        }
    }
    y
}

impl Engine for TransformerEngine {
    fn name(&self) -> &'static str {
        "transformer"
    }

    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Vec<i32> {
        assert_eq!(prompts.len(), self.batch);
        for b in 0..self.batch {
            for l in 0..self.bb.shape.n_layer {
                self.k_cache[b][l].clear();
                self.v_cache[b][l].clear();
            }
        }
        let batch = self.batch;
        let mut out = Vec::with_capacity(batch);
        let Self { bb, k_cache, v_cache, last, .. } = self;
        let (d, nh) = (bb.shape.d_model, bb.shape.attn_heads);
        for b in 0..batch {
            // token-by-token prompt ingestion: every position attends over
            // the growing cache — the O(T^2) prefill of Lemma 2.3
            let mut logits = vec![0.0f32; bb.shape.vocab];
            let (kc_b, vc_b) = (&mut k_cache[b], &mut v_cache[b]);
            for &tok in &prompts[b] {
                logits = bb.decode_one(tok, |li, qkv| {
                    mix_attn(d, nh, &mut kc_b[li], &mut vc_b[li], qkv)
                });
            }
            let next = bb.greedy(&logits);
            last[b] = next;
            out.push(next);
        }
        out
    }

    fn decode(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch);
        let Self { bb, k_cache, v_cache, last, .. } = self;
        let (d, nh) = (bb.shape.d_model, bb.shape.attn_heads);
        for b in 0..last.len() {
            let tok = last[b];
            let (kc_b, vc_b) = (&mut k_cache[b], &mut v_cache[b]);
            let logits = bb.decode_one(tok, |li, qkv| {
                mix_attn(d, nh, &mut kc_b[li], &mut vc_b[li], qkv)
            });
            let next = bb.greedy(&logits);
            last[b] = next;
            out.push(next);
        }
        out
    }

    fn state_bytes(&self) -> u64 {
        let mut total = 0u64;
        for b in 0..self.batch {
            for l in 0..self.bb.shape.n_layer {
                total += ((self.k_cache[b][l].len() + self.v_cache[b][l].len()) * 4) as u64;
            }
        }
        total
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_generation;

    #[test]
    fn kv_cache_twice_conv_cache_rate() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = TransformerEngine::new(&shape, 1, 3);
        eng.prefill(&[vec![1; 8]]);
        let a = eng.state_bytes();
        eng.decode();
        let b = eng.state_bytes();
        // 2 tensors (K and V) of D floats per layer per token
        let per_tok = (2 * shape.n_layer * shape.d_model * 4) as u64;
        assert_eq!(b - a, per_tok);
    }

    #[test]
    fn attention_weights_normalized() {
        // single-head sanity: with identical k rows the attention output is
        // the mean of v rows
        let d = 4;
        let mut kc = vec![1.0f32; 2 * d]; // two cached rows of ones
        let mut vc = vec![0.0f32; 2 * d];
        for c in 0..d {
            vc[c] = 2.0;
            vc[d + c] = 4.0;
        }
        let qkv: Vec<f32> = vec![1.0; 3 * d]
            .iter()
            .enumerate()
            .map(|(i, _)| if i < d { 1.0 } else { 1.0 })
            .collect();
        // new token's k/v: ones and ones -> cache rows become 3
        let y = mix_attn(d, 1, &mut kc, &mut vc, &qkv);
        // all three rows equal score -> y = mean(2, 4, 1) per channel
        for c in 0..d {
            assert!((y[c] - (2.0 + 4.0 + 1.0) / 3.0).abs() < 1e-5, "{}", y[c]);
        }
    }

    #[test]
    fn generation_runs() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = TransformerEngine::new(&shape, 2, 9);
        let r = run_generation(&mut eng, &[vec![1, 2], vec![3, 4]], 4);
        assert_eq!(r.tokens, 8);
    }
}
