//! KV-cached Transformer engine (Lemma 2.3): O(t) attention per token with
//! an O(L) cache of two tensors per layer — the memory profile that caps
//! Transformer batch sizes in Figure 1.1.

use super::backbone::{Backbone, DecodeScratch};
use super::shapes::LmShape;
use super::Engine;
use crate::session::{SessionError, SessionState};

/// Engine tag stamped into [`SessionState`] snapshots.
pub const STATE_TAG: &str = "transformer";

pub struct TransformerEngine {
    bb: Backbone,
    batch: usize,
    /// K and V caches: [B][layer][t * D], growing per token.
    k_cache: Vec<Vec<Vec<f32>>>,
    v_cache: Vec<Vec<Vec<f32>>>,
    last: Vec<i32>,
    /// Token-step scratch (serial engine: one set for all rows).
    scratch: DecodeScratch,
    /// Attention-score scratch, grown to the cache length as needed.
    scores: Vec<f32>,
}

impl TransformerEngine {
    pub fn new(shape: &LmShape, batch: usize, seed: u64) -> TransformerEngine {
        TransformerEngine {
            bb: Backbone::new(shape, seed),
            batch,
            k_cache: vec![vec![Vec::new(); shape.n_layer]; batch],
            v_cache: vec![vec![Vec::new(); shape.n_layer]; batch],
            last: vec![0; batch],
            scratch: DecodeScratch::new(shape),
            scores: Vec::new(),
        }
    }

    pub fn shape(&self) -> &LmShape {
        &self.bb.shape
    }

    /// Clear one row's KV cache (slot recycling).
    pub fn reset_row(&mut self, b: usize) {
        for l in 0..self.bb.shape.n_layer {
            self.k_cache[b][l].clear();
            self.v_cache[b][l].clear();
        }
        self.last[b] = 0;
    }

    /// Feed tokens through one row without resetting it; returns the greedy
    /// token after the last fed token (row's `last` if `tokens` is empty).
    pub fn feed_row(&mut self, b: usize, tokens: &[i32]) -> i32 {
        if tokens.is_empty() {
            return self.last[b];
        }
        let Self { bb, k_cache, v_cache, last, scratch, scores, .. } = self;
        let (d, nh) = (bb.shape.d_model, bb.shape.attn_heads);
        let (kc_b, vc_b) = (&mut k_cache[b], &mut v_cache[b]);
        for &tok in tokens {
            bb.decode_one(tok, scratch, |li, qkv, out| {
                mix_attn(d, nh, &mut kc_b[li], &mut vc_b[li], qkv, scores, out)
            });
        }
        let next = bb.greedy(&scratch.logits);
        last[b] = next;
        next
    }

    /// Prefill a single row with a prompt; returns the first greedy token.
    pub fn prefill_row(&mut self, b: usize, prompt: &[i32]) -> i32 {
        self.reset_row(b);
        self.feed_row(b, prompt)
    }

    /// One decode step for a single row.
    pub fn decode_row(&mut self, b: usize) -> i32 {
        let tok = self.last[b];
        self.feed_row(b, &[tok])
    }

    /// Snapshot one row's KV cache.  Unlike the recurrent engine this blob
    /// is O(t) — it grows with everything the row has consumed, which is
    /// exactly the contrast the paper draws (Lemma 2.2 vs 2.3) and what the
    /// session bench reports.
    pub fn snapshot_row(&self, b: usize) -> SessionState {
        let mut st = SessionState::new(STATE_TAG, self.last[b]);
        for l in 0..self.bb.shape.n_layer {
            st.push_plane(&format!("k.{l}"), self.k_cache[b][l].clone());
            st.push_plane(&format!("v.{l}"), self.v_cache[b][l].clone());
        }
        st
    }

    /// Reinstall a KV snapshot into one row.  Cache lengths vary with the
    /// consumed transcript, so validation checks layer count and row
    /// alignment rather than a fixed size.
    pub fn restore_row(&mut self, b: usize, st: &SessionState) -> Result<(), SessionError> {
        st.check_engine(STATE_TAG)?;
        let d = self.bb.shape.d_model;
        for l in 0..self.bb.shape.n_layer {
            for prefix in ["k", "v"] {
                let name = format!("{prefix}.{l}");
                let p = st
                    .plane(&name)
                    .ok_or_else(|| SessionError::MissingPlane { plane: name.clone() })?;
                if p.len() % d != 0 {
                    return Err(SessionError::Corrupt(format!(
                        "plane '{name}' length {} is not a multiple of d_model {d}",
                        p.len()
                    )));
                }
            }
        }
        for l in 0..self.bb.shape.n_layer {
            self.k_cache[b][l] = st.plane(&format!("k.{l}")).unwrap().to_vec();
            self.v_cache[b][l] = st.plane(&format!("v.{l}")).unwrap().to_vec();
        }
        self.last[b] = st.last_token;
        Ok(())
    }

    /// KV bytes one row currently holds.
    pub fn row_state_bytes(&self, b: usize) -> u64 {
        let mut total = 0u64;
        for l in 0..self.bb.shape.n_layer {
            total += ((self.k_cache[b][l].len() + self.v_cache[b][l].len()) * 4) as u64;
        }
        total
    }
}

/// Multi-head causal attention over the cache for a single new position,
/// written into `y` (fully overwritten); `scores` is reusable scratch.
fn mix_attn(
    d: usize,
    nh: usize,
    kc: &mut Vec<f32>,
    vc: &mut Vec<f32>,
    qkv: &[f32],
    scores: &mut Vec<f32>,
    y: &mut [f32],
) {
    let hd = d / nh;
    let (q, rest) = qkv.split_at(d);
    let (k, v) = rest.split_at(d);
    kc.extend_from_slice(k);
    vc.extend_from_slice(v);
    let t = kc.len() / d;
    let scale = 1.0 / (hd as f32).sqrt();
    y.fill(0.0);
    scores.clear();
    scores.resize(t, 0.0);
    for h in 0..nh {
        let off = h * hd;
        // scores over the whole cache (O(t * hd))
        let mut max_s = f32::MIN;
        for j in 0..t {
            let mut s = 0.0f32;
            let krow = &kc[j * d + off..j * d + off + hd];
            for (a, b) in q[off..off + hd].iter().zip(krow) {
                s += a * b;
            }
            let s = s * scale;
            scores[j] = s;
            max_s = max_s.max(s);
        }
        let mut denom = 0.0f32;
        for s in scores.iter_mut().take(t) {
            *s = (*s - max_s).exp();
            denom += *s;
        }
        for j in 0..t {
            let w = scores[j] / denom;
            let vrow = &vc[j * d + off..j * d + off + hd];
            for (o, &b) in y[off..off + hd].iter_mut().zip(vrow) {
                *o += w * b;
            }
        }
    }
}

impl Engine for TransformerEngine {
    fn name(&self) -> &'static str {
        "transformer"
    }

    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Vec<i32> {
        assert_eq!(prompts.len(), self.batch);
        // token-by-token prompt ingestion: every position attends over
        // the growing cache — the O(T^2) prefill of Lemma 2.3
        (0..self.batch).map(|b| self.prefill_row(b, &prompts[b])).collect()
    }

    fn decode(&mut self) -> Vec<i32> {
        (0..self.batch).map(|b| self.decode_row(b)).collect()
    }

    fn state_bytes(&self) -> u64 {
        let mut total = 0u64;
        for b in 0..self.batch {
            for l in 0..self.bb.shape.n_layer {
                total += ((self.k_cache[b][l].len() + self.v_cache[b][l].len()) * 4) as u64;
            }
        }
        total
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_generation;

    #[test]
    fn kv_cache_twice_conv_cache_rate() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = TransformerEngine::new(&shape, 1, 3);
        eng.prefill(&[vec![1; 8]]);
        let a = eng.state_bytes();
        eng.decode();
        let b = eng.state_bytes();
        // 2 tensors (K and V) of D floats per layer per token
        let per_tok = (2 * shape.n_layer * shape.d_model * 4) as u64;
        assert_eq!(b - a, per_tok);
    }

    #[test]
    fn attention_weights_normalized() {
        // single-head sanity: with identical k rows the attention output is
        // the mean of v rows
        let d = 4;
        let mut kc = vec![1.0f32; 2 * d]; // two cached rows of ones
        let mut vc = vec![0.0f32; 2 * d];
        for c in 0..d {
            vc[c] = 2.0;
            vc[d + c] = 4.0;
        }
        let qkv: Vec<f32> = vec![1.0; 3 * d]
            .iter()
            .enumerate()
            .map(|(i, _)| if i < d { 1.0 } else { 1.0 })
            .collect();
        // new token's k/v: ones and ones -> cache rows become 3
        let mut y = vec![0.0f32; d];
        let mut scores = Vec::new();
        mix_attn(d, 1, &mut kc, &mut vc, &qkv, &mut scores, &mut y);
        // all three rows equal score -> y = mean(2, 4, 1) per channel
        for c in 0..d {
            assert!((y[c] - (2.0 + 4.0 + 1.0) / 3.0).abs() < 1e-5, "{}", y[c]);
        }
    }

    #[test]
    fn snapshot_restore_resume_is_bit_identical() {
        let shape = LmShape::bench("nano").unwrap();
        let mut a = TransformerEngine::new(&shape, 2, 13);
        a.prefill_row(0, &[3, 1, 4, 1, 5]);
        for _ in 0..3 {
            a.decode_row(0);
        }
        let snap = a.snapshot_row(0);
        assert!(snap.state_bytes() > 0);
        let cont_a: Vec<i32> = (0..5).map(|_| a.decode_row(0)).collect();
        let mut b = TransformerEngine::new(&shape, 2, 13);
        b.restore_row(1, &snap).unwrap();
        let cont_b: Vec<i32> = (0..5).map(|_| b.decode_row(1)).collect();
        assert_eq!(cont_a, cont_b);
    }

    #[test]
    fn snapshot_grows_with_transcript_unlike_recurrent() {
        // the Lemma 2.2 / 2.3 contrast at the session layer: KV snapshots
        // grow per consumed token, recurrent snapshots do not
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = TransformerEngine::new(&shape, 1, 3);
        eng.prefill_row(0, &[1; 4]);
        let small = eng.snapshot_row(0).state_bytes();
        eng.feed_row(0, &[2; 16]);
        let big = eng.snapshot_row(0).state_bytes();
        assert!(big > small);
        let mut rec = crate::engine::recurrent::RecurrentEngine::new(&shape, 1, 3);
        rec.prefill_row(0, &[1; 4]);
        let r_small = rec.snapshot_row(0).state_bytes();
        rec.feed_row(0, &[2; 16]);
        assert_eq!(rec.snapshot_row(0).state_bytes(), r_small, "O(1) state");
    }

    #[test]
    fn restore_rejects_foreign_blob() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = TransformerEngine::new(&shape, 1, 3);
        let mut snap = eng.snapshot_row(0);
        snap.engine = "laughing-hyena".into();
        assert!(eng.restore_row(0, &snap).is_err());
    }

    #[test]
    fn generation_runs() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = TransformerEngine::new(&shape, 2, 9);
        let r = run_generation(&mut eng, &[vec![1, 2], vec![3, 4]], 4);
        assert_eq!(r.tokens, 8);
    }
}
