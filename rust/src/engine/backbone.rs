//! Shared decoder backbone for the native engines: embeddings, pre/post
//! projections, MLP and LM head.  Engines differ only in the token-mixing
//! core, injected as a closure — `mixer(layer, qkv, out)` writes the mixed
//! `[D]` vector for single-token decode and `mixer_block(layer, qkv_t, t)`
//! returns the mixed `[T, D]` block for whole-prompt prefill.
//!
//! Single-token decode is allocation-free: every intermediate lives in a
//! caller-owned [`DecodeScratch`], so the per-token hot loop touches only
//! pre-allocated buffers (the engines keep one scratch per batch row and
//! reuse it for every token).

use std::time::Instant;

use super::linear::{argmax, gelu, layer_norm, Dense};
use super::shapes::LmShape;
use crate::util::pool::Pool;
use crate::util::Prng;

/// Per-stage hot-path timings for one profiled request, in nanoseconds.
/// Plain `Copy` counters — recording is allocation-free and the struct
/// lives inside per-row scratch, so profiled rows never contend.  The
/// stages interleave per token, so these are per-request aggregates,
/// not a timeline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageTimes {
    /// Short-conv window contraction inside the fused mixer.
    pub short_conv_ns: u64,
    /// Modal SSM state sweep inside the fused mixer.
    pub modal_sweep_ns: u64,
    /// qkv projection GEMVs.
    pub qkv_ns: u64,
    /// Post-mixer out-projection GEMVs.
    pub out_proj_ns: u64,
    /// MLP up + gelu + down.
    pub mlp_ns: u64,
    /// LM-head GEMV.
    pub lm_head_ns: u64,
    /// Tokens these aggregates cover (prefill + decode + resume feeds).
    pub tokens: u64,
}

impl StageTimes {
    pub fn add(&mut self, o: &StageTimes) {
        self.short_conv_ns += o.short_conv_ns;
        self.modal_sweep_ns += o.modal_sweep_ns;
        self.qkv_ns += o.qkv_ns;
        self.out_proj_ns += o.out_proj_ns;
        self.mlp_ns += o.mlp_ns;
        self.lm_head_ns += o.lm_head_ns;
        self.tokens += o.tokens;
    }

    /// Sum of every instrumented stage.
    pub fn total_ns(&self) -> u64 {
        self.short_conv_ns
            + self.modal_sweep_ns
            + self.qkv_ns
            + self.out_proj_ns
            + self.mlp_ns
            + self.lm_head_ns
    }

    /// (stage name, nanoseconds) pairs in fixed order — the single list
    /// both the `lh_engine_*` histograms and the trace "engine" hop
    /// spans are built from.
    pub fn stages(&self) -> [(&'static str, u64); 6] {
        [
            ("short_conv", self.short_conv_ns),
            ("modal_sweep", self.modal_sweep_ns),
            ("qkv", self.qkv_ns),
            ("out_proj", self.out_proj_ns),
            ("mlp", self.mlp_ns),
            ("lm_head", self.lm_head_ns),
        ]
    }
}

/// Reusable buffers for [`Backbone::decode_one`]: everything the
/// single-token forward pass needs, allocated once per row and reused for
/// every token so steady-state decode performs zero heap allocations.
pub struct DecodeScratch {
    /// Residual stream [D].
    x: Vec<f32>,
    /// Normed hidden [D].
    h: Vec<f32>,
    /// Projected qkv [3D].
    qkv: Vec<f32>,
    /// Mixer output [D].
    mixed: Vec<f32>,
    /// Out/MLP projection output [D].
    proj: Vec<f32>,
    /// MLP hidden [mlp_mult * D].
    mid: Vec<f32>,
    /// LM-head output [V]; after [`Backbone::decode_one`] returns this
    /// holds the logits of the decoded token.
    pub logits: Vec<f32>,
}

impl DecodeScratch {
    pub fn new(shape: &LmShape) -> DecodeScratch {
        let d = shape.d_model;
        DecodeScratch {
            x: vec![0.0; d],
            h: vec![0.0; d],
            qkv: vec![0.0; 3 * d],
            mixed: vec![0.0; d],
            proj: vec![0.0; d],
            mid: vec![0.0; shape.mlp_mult * d],
            logits: vec![0.0; shape.vocab],
        }
    }
}

pub struct Layer {
    pub qkv: Dense,  // [D, 3D]
    pub out: Dense,  // [D, D]
    pub mlp1: Dense, // [D, mD]
    pub mlp2: Dense, // [mD, D]
}

pub struct Backbone {
    pub shape: LmShape,
    /// Embedding table [V, D] (rows are token vectors).
    pub embed: Vec<f32>,
    pub layers: Vec<Layer>,
    pub lm_head: Dense, // [D, V]
}

impl Backbone {
    pub fn new(shape: &LmShape, seed: u64) -> Backbone {
        shape.validate().expect("invalid LmShape");
        let mut rng = Prng::new(seed);
        let d = shape.d_model;
        let embed: Vec<f32> = (0..shape.vocab * d)
            .map(|_| (rng.normal() * 0.02) as f32)
            .collect();
        // Per-layer weight init fans out over the shared persistent pool
        // (the bulk of the coordinator's engine-factory cost). Each layer
        // draws from its own splitmix-derived stream, so construction is
        // deterministic per seed at any thread count.
        let layers = Pool::auto().map((0..shape.n_layer).collect::<Vec<usize>>(), |li| {
            let mut lr = Prng::derived(seed, li as u64);
            Layer {
                qkv: Dense::random(d, 3 * d, &mut lr),
                out: Dense::random(d, d, &mut lr),
                mlp1: Dense::random(d, shape.mlp_mult * d, &mut lr),
                mlp2: Dense::random(shape.mlp_mult * d, d, &mut lr),
            }
        });
        let lm_head = Dense::random(d, shape.vocab, &mut rng);
        Backbone { shape: shape.clone(), embed, layers, lm_head }
    }

    pub fn weights_bytes(&self) -> u64 {
        let mut b = (self.embed.len() * 4) as u64 + self.lm_head.bytes();
        for l in &self.layers {
            b += l.qkv.bytes() + l.out.bytes() + l.mlp1.bytes() + l.mlp2.bytes();
        }
        b
    }

    /// Decode one token for one sequence into `scratch.logits`, touching
    /// only the caller's pre-allocated [`DecodeScratch`] (zero heap
    /// allocations).  `mixer(layer, qkv, out)` must write *every* element
    /// of the `[D]` output slice (it is not pre-zeroed between tokens).
    pub fn decode_one(
        &self,
        token: i32,
        scratch: &mut DecodeScratch,
        mut mixer: impl FnMut(usize, &[f32], &mut [f32]),
    ) {
        let d = self.shape.d_model;
        let DecodeScratch { x, h, qkv, mixed, proj, mid, logits } = scratch;
        x.copy_from_slice(&self.embed[token as usize * d..(token as usize + 1) * d]);
        for (li, layer) in self.layers.iter().enumerate() {
            h.copy_from_slice(x);
            layer_norm(h);
            layer.qkv.apply(h, qkv);
            mixer(li, qkv, mixed);
            layer.out.apply(mixed, proj);
            for (xi, p) in x.iter_mut().zip(proj.iter()) {
                *xi += *p;
            }
            h.copy_from_slice(x);
            layer_norm(h);
            layer.mlp1.apply(h, mid);
            for v in mid.iter_mut() {
                *v = gelu(*v);
            }
            layer.mlp2.apply(mid, proj);
            for (xi, p) in x.iter_mut().zip(proj.iter()) {
                *xi += *p;
            }
        }
        layer_norm(x);
        self.lm_head.apply(x, logits);
    }

    /// [`Backbone::decode_one`] with per-stage wall-clock attribution
    /// into `t` — the sampled-profiling path.  The arithmetic is the
    /// *same statements in the same order* as the unprofiled method
    /// (timers only read the clock between stages), so a profiled
    /// request's tokens are bit-identical to an unprofiled one's; the
    /// mixer's own short-conv/modal-sweep split is recorded by the
    /// caller's closure (see `engine::recurrent`).
    pub fn decode_one_timed(
        &self,
        token: i32,
        scratch: &mut DecodeScratch,
        mut mixer: impl FnMut(usize, &[f32], &mut [f32]),
        t: &mut StageTimes,
    ) {
        let d = self.shape.d_model;
        let DecodeScratch { x, h, qkv, mixed, proj, mid, logits } = scratch;
        x.copy_from_slice(&self.embed[token as usize * d..(token as usize + 1) * d]);
        for (li, layer) in self.layers.iter().enumerate() {
            h.copy_from_slice(x);
            layer_norm(h);
            let t0 = Instant::now();
            layer.qkv.apply(h, qkv);
            t.qkv_ns += t0.elapsed().as_nanos() as u64;
            mixer(li, qkv, mixed);
            let t0 = Instant::now();
            layer.out.apply(mixed, proj);
            t.out_proj_ns += t0.elapsed().as_nanos() as u64;
            for (xi, p) in x.iter_mut().zip(proj.iter()) {
                *xi += *p;
            }
            h.copy_from_slice(x);
            layer_norm(h);
            let t0 = Instant::now();
            layer.mlp1.apply(h, mid);
            for v in mid.iter_mut() {
                *v = gelu(*v);
            }
            layer.mlp2.apply(mid, proj);
            t.mlp_ns += t0.elapsed().as_nanos() as u64;
            for (xi, p) in x.iter_mut().zip(proj.iter()) {
                *xi += *p;
            }
        }
        layer_norm(x);
        let t0 = Instant::now();
        self.lm_head.apply(x, logits);
        t.lm_head_ns += t0.elapsed().as_nanos() as u64;
        t.tokens += 1;
    }

    /// Block forward over a whole prompt for one sequence; the mixer sees
    /// qkv for all T positions ([T, 3D] row-major) and returns [T, D].
    /// Returns the logits at the final position.
    pub fn prefill_block(
        &self,
        tokens: &[i32],
        mut mixer: impl FnMut(usize, &[f32], usize) -> Vec<f32>,
    ) -> Vec<f32> {
        let d = self.shape.d_model;
        let t = tokens.len();
        let mut x = vec![0.0f32; t * d];
        for (p, &tok) in tokens.iter().enumerate() {
            x[p * d..(p + 1) * d]
                .copy_from_slice(&self.embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut qkv = vec![0.0f32; t * 3 * d];
        let mut proj = vec![0.0f32; t * d];
        let mut mid = vec![0.0f32; t * self.shape.mlp_mult * d];
        for (li, layer) in self.layers.iter().enumerate() {
            let mut h = x.clone();
            for p in 0..t {
                layer_norm(&mut h[p * d..(p + 1) * d]);
            }
            layer.qkv.apply_batch(&h, &mut qkv, t);
            let mixed = mixer(li, &qkv, t);
            layer.out.apply_batch(&mixed, &mut proj, t);
            for (xi, p) in x.iter_mut().zip(&proj) {
                *xi += p;
            }
            let mut h2 = x.clone();
            for p in 0..t {
                layer_norm(&mut h2[p * d..(p + 1) * d]);
            }
            layer.mlp1.apply_batch(&h2, &mut mid, t);
            for v in mid.iter_mut() {
                *v = gelu(*v);
            }
            layer.mlp2.apply_batch(&mid, &mut proj, t);
            for (xi, p) in x.iter_mut().zip(&proj) {
                *xi += p;
            }
        }
        let last = &mut x[(t - 1) * d..t * d];
        layer_norm(last);
        let mut logits = vec![0.0f32; self.shape.vocab];
        self.lm_head.apply(last, &mut logits);
        logits
    }

    pub fn greedy(&self, logits: &[f32]) -> i32 {
        argmax(logits) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_one_produces_finite_logits() {
        let shape = LmShape::bench("nano").unwrap();
        let bb = Backbone::new(&shape, 1);
        let mut scratch = DecodeScratch::new(&shape);
        bb.decode_one(3, &mut scratch, |_li, qkv, out| {
            // identity-ish mixer: take the v third
            let d = shape.d_model;
            out.copy_from_slice(&qkv[2 * d..3 * d]);
        });
        assert_eq!(scratch.logits.len(), shape.vocab);
        assert!(scratch.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn block_matches_single_for_pointwise_mixer() {
        // with a mixer that has no cross-token interaction, prefill_block's
        // final logits equal decode_one on the last token (residual stream
        // depends only on the current token then)
        let shape = LmShape::bench("nano").unwrap();
        let bb = Backbone::new(&shape, 2);
        let d = shape.d_model;
        let toks = [5, 9, 13];
        let block = bb.prefill_block(&toks, |_li, qkv, t| {
            let mut out = vec![0.0f32; t * d];
            for p in 0..t {
                out[p * d..(p + 1) * d]
                    .copy_from_slice(&qkv[p * 3 * d + 2 * d..p * 3 * d + 3 * d]);
            }
            out
        });
        let mut scratch = DecodeScratch::new(&shape);
        bb.decode_one(13, &mut scratch, |_li, qkv, out| {
            out.copy_from_slice(&qkv[2 * d..3 * d]);
        });
        for (a, b) in block.iter().zip(&scratch.logits) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn timed_decode_is_bit_identical_and_attributes_stages() {
        // the profiled path runs the same statements in the same order;
        // only the clock is read between stages — logits must match
        // bit-for-bit and every GEMV stage must receive attribution
        let shape = LmShape::bench("nano").unwrap();
        let bb = Backbone::new(&shape, 5);
        let d = shape.d_model;
        let mixer = |_li: usize, qkv: &[f32], out: &mut [f32]| {
            out.copy_from_slice(&qkv[2 * d..3 * d]);
        };
        let mut plain = DecodeScratch::new(&shape);
        bb.decode_one(11, &mut plain, mixer);
        let mut timed = DecodeScratch::new(&shape);
        let mut t = StageTimes::default();
        bb.decode_one_timed(11, &mut timed, mixer, &mut t);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&plain.logits), bits(&timed.logits));
        assert_eq!(t.tokens, 1);
        assert!(t.qkv_ns > 0 && t.out_proj_ns > 0 && t.mlp_ns > 0 && t.lm_head_ns > 0);
        assert_eq!(t.total_ns(), t.stages().iter().map(|(_, ns)| ns).sum::<u64>());
    }

    #[test]
    fn decode_one_is_repeatable_with_reused_scratch() {
        // the scratch is not cleared between tokens; a second pass over the
        // same token with the same mixer state must reproduce the logits
        let shape = LmShape::bench("nano").unwrap();
        let bb = Backbone::new(&shape, 7);
        let d = shape.d_model;
        let mut scratch = DecodeScratch::new(&shape);
        bb.decode_one(9, &mut scratch, |_li, qkv, out| {
            out.copy_from_slice(&qkv[2 * d..3 * d]);
        });
        let first = scratch.logits.clone();
        bb.decode_one(42, &mut scratch, |_li, qkv, out| {
            out.copy_from_slice(&qkv[2 * d..3 * d]);
        });
        bb.decode_one(9, &mut scratch, |_li, qkv, out| {
            out.copy_from_slice(&qkv[2 * d..3 * d]);
        });
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&first), bits(&scratch.logits));
    }
}
