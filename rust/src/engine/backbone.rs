//! Shared decoder backbone for the native engines: embeddings, pre/post
//! projections, MLP and LM head.  Engines differ only in the token-mixing
//! core, injected as a closure — `mixer(layer, row, qkv) -> mixed [D]` for
//! single-token decode and `mixer_block(layer, row, qkv_t) -> mixed [T, D]`
//! for whole-prompt prefill.

use super::linear::{argmax, gelu, layer_norm, Dense};
use super::shapes::LmShape;
use crate::util::pool::Pool;
use crate::util::Prng;

pub struct Layer {
    pub qkv: Dense,  // [D, 3D]
    pub out: Dense,  // [D, D]
    pub mlp1: Dense, // [D, mD]
    pub mlp2: Dense, // [mD, D]
}

pub struct Backbone {
    pub shape: LmShape,
    /// Embedding table [V, D] (rows are token vectors).
    pub embed: Vec<f32>,
    pub layers: Vec<Layer>,
    pub lm_head: Dense, // [D, V]
}

impl Backbone {
    pub fn new(shape: &LmShape, seed: u64) -> Backbone {
        let mut rng = Prng::new(seed);
        let d = shape.d_model;
        let embed: Vec<f32> = (0..shape.vocab * d)
            .map(|_| (rng.normal() * 0.02) as f32)
            .collect();
        // Per-layer weight init fans out over the pool (the bulk of the
        // coordinator's engine-factory cost). Each layer draws from its own
        // splitmix-derived stream, so construction is deterministic per
        // seed at any thread count.
        let layers = Pool::auto().map((0..shape.n_layer).collect::<Vec<usize>>(), |li| {
            let mut lr = Prng::derived(seed, li as u64);
            Layer {
                qkv: Dense::random(d, 3 * d, &mut lr),
                out: Dense::random(d, d, &mut lr),
                mlp1: Dense::random(d, shape.mlp_mult * d, &mut lr),
                mlp2: Dense::random(shape.mlp_mult * d, d, &mut lr),
            }
        });
        let lm_head = Dense::random(d, shape.vocab, &mut rng);
        Backbone { shape: shape.clone(), embed, layers, lm_head }
    }

    pub fn weights_bytes(&self) -> u64 {
        let mut b = (self.embed.len() * 4) as u64 + self.lm_head.bytes();
        for l in &self.layers {
            b += l.qkv.bytes() + l.out.bytes() + l.mlp1.bytes() + l.mlp2.bytes();
        }
        b
    }

    /// Decode one token for one sequence; `mixer(layer, qkv) -> mixed [D]`.
    pub fn decode_one(
        &self,
        token: i32,
        mut mixer: impl FnMut(usize, &[f32]) -> Vec<f32>,
    ) -> Vec<f32> {
        let d = self.shape.d_model;
        let mut x: Vec<f32> =
            self.embed[token as usize * d..(token as usize + 1) * d].to_vec();
        let mut qkv = vec![0.0f32; 3 * d];
        let mut proj = vec![0.0f32; d];
        let mut mid = vec![0.0f32; self.shape.mlp_mult * d];
        for (li, layer) in self.layers.iter().enumerate() {
            let mut h = x.clone();
            layer_norm(&mut h);
            layer.qkv.apply(&h, &mut qkv);
            let mixed = mixer(li, &qkv);
            layer.out.apply(&mixed, &mut proj);
            for (xi, p) in x.iter_mut().zip(&proj) {
                *xi += p;
            }
            let mut h2 = x.clone();
            layer_norm(&mut h2);
            layer.mlp1.apply(&h2, &mut mid);
            for v in mid.iter_mut() {
                *v = gelu(*v);
            }
            layer.mlp2.apply(&mid, &mut proj);
            for (xi, p) in x.iter_mut().zip(&proj) {
                *xi += p;
            }
        }
        layer_norm(&mut x);
        let mut logits = vec![0.0f32; self.shape.vocab];
        self.lm_head.apply(&x, &mut logits);
        logits
    }

    /// Block forward over a whole prompt for one sequence; the mixer sees
    /// qkv for all T positions ([T, 3D] row-major) and returns [T, D].
    /// Returns the logits at the final position.
    pub fn prefill_block(
        &self,
        tokens: &[i32],
        mut mixer: impl FnMut(usize, &[f32], usize) -> Vec<f32>,
    ) -> Vec<f32> {
        let d = self.shape.d_model;
        let t = tokens.len();
        let mut x = vec![0.0f32; t * d];
        for (p, &tok) in tokens.iter().enumerate() {
            x[p * d..(p + 1) * d]
                .copy_from_slice(&self.embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut qkv = vec![0.0f32; t * 3 * d];
        let mut proj = vec![0.0f32; t * d];
        let mut mid = vec![0.0f32; t * self.shape.mlp_mult * d];
        for (li, layer) in self.layers.iter().enumerate() {
            let mut h = x.clone();
            for p in 0..t {
                layer_norm(&mut h[p * d..(p + 1) * d]);
            }
            layer.qkv.apply_batch(&h, &mut qkv, t);
            let mixed = mixer(li, &qkv, t);
            layer.out.apply_batch(&mixed, &mut proj, t);
            for (xi, p) in x.iter_mut().zip(&proj) {
                *xi += p;
            }
            let mut h2 = x.clone();
            for p in 0..t {
                layer_norm(&mut h2[p * d..(p + 1) * d]);
            }
            layer.mlp1.apply_batch(&h2, &mut mid, t);
            for v in mid.iter_mut() {
                *v = gelu(*v);
            }
            layer.mlp2.apply_batch(&mid, &mut proj, t);
            for (xi, p) in x.iter_mut().zip(&proj) {
                *xi += p;
            }
        }
        let last = &mut x[(t - 1) * d..t * d];
        layer_norm(last);
        let mut logits = vec![0.0f32; self.shape.vocab];
        self.lm_head.apply(last, &mut logits);
        logits
    }

    pub fn greedy(&self, logits: &[f32]) -> i32 {
        argmax(logits) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_one_produces_finite_logits() {
        let shape = LmShape::bench("nano").unwrap();
        let bb = Backbone::new(&shape, 1);
        let logits = bb.decode_one(3, |_li, qkv| {
            // identity-ish mixer: take the v third
            let d = shape.d_model;
            qkv[2 * d..3 * d].to_vec()
        });
        assert_eq!(logits.len(), shape.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn block_matches_single_for_pointwise_mixer() {
        // with a mixer that has no cross-token interaction, prefill_block's
        // final logits equal decode_one on the last token (residual stream
        // depends only on the current token then)
        let shape = LmShape::bench("nano").unwrap();
        let bb = Backbone::new(&shape, 2);
        let d = shape.d_model;
        let toks = [5, 9, 13];
        let block = bb.prefill_block(&toks, |_li, qkv, t| {
            let mut out = vec![0.0f32; t * d];
            for p in 0..t {
                out[p * d..(p + 1) * d]
                    .copy_from_slice(&qkv[p * 3 * d + 2 * d..p * 3 * d + 3 * d]);
            }
            out
        });
        let single = bb.decode_one(13, |_li, qkv| qkv[2 * d..3 * d].to_vec());
        for (a, b) in block.iter().zip(&single) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
