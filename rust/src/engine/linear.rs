//! f32 dense primitives shared by the native engines: batched GEMV,
//! layer norm, and weight initialization.  The decode hot loop lives here —
//! see EXPERIMENTS.md §Perf for the iteration log on `matvec`.

use crate::util::Prng;

/// Row-major f32 weight matrix [rows=in, cols=out] (x @ W layout).
pub struct Dense {
    pub w: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

impl Dense {
    pub fn random(rows: usize, cols: usize, rng: &mut Prng) -> Dense {
        let scale = 1.0 / (rows as f64).sqrt();
        let w = (0..rows * cols)
            .map(|_| (rng.normal() * scale) as f32)
            .collect();
        Dense { w, rows, cols }
    }

    /// y = x @ W for a single row x [in] -> y [out].
    /// Row-major W makes this a sum of scaled rows — sequential access.
    pub fn apply(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.w[i * self.cols..(i + 1) * self.cols];
            for (yo, &wv) in y.iter_mut().zip(row) {
                *yo += xi * wv;
            }
        }
    }

    /// Batched apply: x [b, in] row-major -> y [b, out].
    pub fn apply_batch(&self, x: &[f32], y: &mut [f32], b: usize) {
        for r in 0..b {
            self.apply(
                &x[r * self.rows..(r + 1) * self.rows],
                &mut y[r * self.cols..(r + 1) * self.cols],
            );
        }
    }

    pub fn bytes(&self) -> u64 {
        (self.w.len() * 4) as u64
    }
}

/// In-place layer norm (unit gain, zero bias — engines benchmark compute
/// cost, not learned statistics).
pub fn layer_norm(x: &mut [f32]) {
    let n = x.len() as f32;
    let mean: f32 = x.iter().sum::<f32>() / n;
    let var: f32 = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for v in x.iter_mut() {
        *v = (*v - mean) * inv;
    }
}

/// GELU (tanh approximation).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.7978845608 * (x + 0.044715 * x * x * x)).tanh())
}

/// Greedy argmax.
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::MIN;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_matches_naive() {
        let mut rng = Prng::new(1);
        let d = Dense::random(5, 3, &mut rng);
        let x: Vec<f32> = (0..5).map(|i| i as f32 - 2.0).collect();
        let mut y = vec![0.0; 3];
        d.apply(&x, &mut y);
        for c in 0..3 {
            let want: f32 = (0..5).map(|r| x[r] * d.w[r * 3 + c]).sum();
            assert!((y[c] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut x: Vec<f32> = (0..64).map(|i| (i as f32) * 0.3 - 5.0).collect();
        layer_norm(&mut x);
        let mean: f32 = x.iter().sum::<f32>() / 64.0;
        let var: f32 = x.iter().map(|v| v * v).sum::<f32>() / 64.0;
        assert!(mean.abs() < 1e-4);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0]), 1);
    }
}
