//! Conv-mode LCSM engine (Hyena/H3 without distillation, Lemma 2.1): the
//! long convolution is evaluated against the cached gated-signal history,
//! O(t) per channel per token with O(L)-growing memory — exactly the cost
//! profile LaughingHyena removes.

use super::backbone::{Backbone, DecodeScratch};
use super::shapes::{LmShape, SHORT_TAPS};
use super::Engine;
use crate::util::Prng;

pub struct ConvCacheEngine {
    bb: Backbone,
    /// Long filter taps per head [n_layer][heads][L] (h0 first).
    filters: Vec<Vec<Vec<f32>>>,
    batch: usize,
    /// Gated-signal history per sequence/layer/channel: [B][layer][t * D]
    /// (row-major over time; grows every token — the paper's O(L) cache).
    hist: Vec<Vec<Vec<f32>>>,
    /// Short-conv buffers, as in the recurrent engine (shift-based here:
    /// this engine exists to measure the O(t) long-conv cost, not to win).
    sc: Vec<Vec<Vec<f32>>>,
    last: Vec<i32>,
    /// Token-step scratch (serial engine: one set for all rows).
    scratch: DecodeScratch,
    qkv_c: Vec<f32>,
}

impl ConvCacheEngine {
    pub fn new(shape: &LmShape, batch: usize, seed: u64) -> ConvCacheEngine {
        let bb = Backbone::new(shape, seed);
        let mut rng = Prng::new(seed ^ 0xF117E5);
        // decaying random filters, length = seq_len
        let filters = (0..shape.n_layer)
            .map(|_| {
                (0..shape.heads)
                    .map(|_| {
                        (0..shape.seq_len)
                            .map(|t| {
                                let dec = (-(t as f64) / (shape.seq_len as f64 / 4.0)).exp();
                                (rng.normal() * 0.3 * dec) as f32
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let d = shape.d_model;
        let kw = shape.short_kw;
        ConvCacheEngine {
            bb,
            filters,
            batch,
            hist: vec![vec![Vec::new(); shape.n_layer]; batch],
            sc: vec![vec![vec![0.0; 3 * d * (kw - 1)]; shape.n_layer]; batch],
            last: vec![0; batch],
            scratch: DecodeScratch::new(shape),
            qkv_c: vec![0.0; 3 * d],
        }
    }
}

/// One conv-mode mixer step: push z_t = k*v into the history, evaluate the
/// causal convolution at the newest position (O(t D)), gate with q.
/// `kw == 1` skips the short-conv window entirely.
#[allow(clippy::too_many_arguments)]
fn mix_conv(
    d: usize,
    kw: usize,
    group: usize,
    filters_layer: &[Vec<f32>],
    buf: &mut [f32],
    hist: &mut Vec<f32>,
    qkv: &[f32],
    qkv_c: &mut [f32],
    out: &mut [f32],
) {
    let tail = kw - 1;
    let cur = SHORT_TAPS[tail];
    if tail == 0 {
        for (o, &x) in qkv_c.iter_mut().zip(qkv) {
            *o = cur * x;
        }
    } else {
        let taps = &SHORT_TAPS[..tail];
        for c in 0..3 * d {
            let win = &mut buf[c * tail..(c + 1) * tail];
            let mut acc = cur * qkv[c];
            for (j, &w) in taps.iter().enumerate() {
                acc += w * win[j];
            }
            qkv_c[c] = acc;
            // roll the window (oldest-first layout)
            win.copy_within(1.., 0);
            win[tail - 1] = qkv[c];
        }
    }
    let (q, rest) = qkv_c.split_at(d);
    let (k, v) = rest.split_at(d);
    // append z_t
    let t0 = hist.len() / d;
    hist.resize((t0 + 1) * d, 0.0);
    for c in 0..d {
        hist[t0 * d + c] = k[c] * v[c];
    }
    let t = t0 + 1;
    // y_c = sum_{j=0..t-1} h[t-1-j] z_j  — O(t) per channel
    for c in 0..d {
        let h = &filters_layer[c / group];
        let kmax = (t - 1).min(h.len() - 1);
        let mut acc = 0.0f32;
        for j in 0..=kmax {
            acc += h[j] * hist[(t - 1 - j) * d + c];
        }
        out[c] = q[c] * acc;
    }
}

impl Engine for ConvCacheEngine {
    fn name(&self) -> &'static str {
        "hyena-conv"
    }

    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Vec<i32> {
        assert_eq!(prompts.len(), self.batch);
        for b in 0..self.batch {
            for l in 0..self.bb.shape.n_layer {
                self.hist[b][l].clear();
                self.sc[b][l].fill(0.0);
            }
        }
        let batch = self.batch;
        let mut out = Vec::with_capacity(batch);
        let Self { bb, filters, hist, sc, last, scratch, qkv_c, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        for b in 0..batch {
            // empty prompts must see zero logits (argmax -> token 0), not
            // whatever the previous row left in the shared scratch
            scratch.logits.fill(0.0);
            let (h_b, sc_b) = (&mut hist[b], &mut sc[b]);
            for &tok in &prompts[b] {
                bb.decode_one(tok, scratch, |li, qkv, y| {
                    mix_conv(
                        d, kw, group, &filters[li], &mut sc_b[li], &mut h_b[li], qkv, qkv_c, y,
                    )
                });
            }
            let next = bb.greedy(&scratch.logits);
            last[b] = next;
            out.push(next);
        }
        out
    }

    fn decode(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch);
        let Self { bb, filters, hist, sc, last, scratch, qkv_c, .. } = self;
        let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
        let group = d / bb.shape.heads;
        for b in 0..last.len() {
            let tok = last[b];
            let (h_b, sc_b) = (&mut hist[b], &mut sc[b]);
            bb.decode_one(tok, scratch, |li, qkv, y| {
                mix_conv(
                    d, kw, group, &filters[li], &mut sc_b[li], &mut h_b[li], qkv, qkv_c, y,
                )
            });
            let next = bb.greedy(&scratch.logits);
            last[b] = next;
            out.push(next);
        }
        out
    }

    fn state_bytes(&self) -> u64 {
        let mut total = 0u64;
        for b in 0..self.batch {
            for l in 0..self.bb.shape.n_layer {
                total += (self.hist[b][l].len() * 4) as u64;
                total += (self.sc[b][l].len() * 4) as u64;
            }
        }
        total
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_generation;

    #[test]
    fn cache_grows_linearly_with_tokens() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = ConvCacheEngine::new(&shape, 1, 3);
        eng.prefill(&[vec![1; 8]]);
        let after_prefill = eng.state_bytes();
        for _ in 0..8 {
            eng.decode();
        }
        let after_decode = eng.state_bytes();
        // 8 prompt + 1 + 8 generated tokens of history
        let per_tok = (shape.n_layer * shape.d_model * 4) as u64;
        assert_eq!(after_decode - after_prefill, 8 * per_tok);
    }

    #[test]
    fn generation_works_end_to_end() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = ConvCacheEngine::new(&shape, 2, 4);
        let r = run_generation(&mut eng, &[vec![1, 2, 3], vec![4, 5, 6]], 5);
        assert_eq!(r.tokens, 10);
        assert!(r.peak_state_bytes > 0);
    }

    #[test]
    fn short_kw_one_generates() {
        // the no-short-conv configuration must also work in conv mode
        let mut shape = LmShape::bench("nano").unwrap();
        shape.short_kw = 1;
        let mut eng = ConvCacheEngine::new(&shape, 1, 4);
        eng.prefill(&[vec![1, 2, 3]]);
        for _ in 0..3 {
            let toks = eng.decode();
            assert!(toks.iter().all(|&t| (t as usize) < shape.vocab));
        }
    }
}
