//! Native generation engines for the §5.4 benchmark suite: three
//! architectures with the exact per-token asymptotics the paper compares
//! (Lemmas 2.1-2.3), doing real float work with randomly initialized
//! weights:
//!
//! * [`recurrent::RecurrentEngine`] — LaughingHyena: distilled modal SSM
//!   per channel, O(d) per token, O(d) state.
//! * [`conv_cache::ConvCacheEngine`] — Hyena/H3 conv mode: cache the gated
//!   signal history, O(t) per token, O(L) state.
//! * [`transformer::TransformerEngine`] — KV-cached attention, O(t) per
//!   token, O(L) state with a much larger constant (2 tensors/layer).
//!
//! Quality experiments (logit errors, downstream impact) do NOT use these —
//! they run the real trained model through [`crate::runtime`]; the engines
//! are for throughput/latency/memory *shape* reproduction at CPU scale.

pub mod backbone;
pub mod conv_cache;
pub mod linear;
pub mod memory;
pub mod modal_sweep;
pub mod recurrent;
pub mod shapes;
pub mod transformer;

pub use shapes::LmShape;

/// A batched auto-regressive generation engine.
pub trait Engine {
    fn name(&self) -> &'static str;
    /// Consume prompts (one per sequence), initialize generation state, and
    /// return the first sampled token per sequence (greedy).
    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Vec<i32>;
    /// One decode step for the whole batch (feeds back the previous
    /// tokens); returns the next token per sequence.
    fn decode(&mut self) -> Vec<i32>;
    /// Bytes of per-generation state currently allocated (kv caches, SSM
    /// states, conv histories) — weights excluded.
    fn state_bytes(&self) -> u64;
    fn batch(&self) -> usize;
}

/// Generate K tokens after prefill and collect simple timing stats.
pub struct GenReport {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub tokens: usize,
    pub peak_state_bytes: u64,
}

/// Drive any engine through the standard (T-prompt, K-token) workload.
pub fn run_generation(engine: &mut dyn Engine, prompts: &[Vec<i32>], k: usize) -> GenReport {
    let t0 = std::time::Instant::now();
    let _first = engine.prefill(prompts);
    let prefill_s = t0.elapsed().as_secs_f64();
    let mut peak = engine.state_bytes();
    let t1 = std::time::Instant::now();
    for _ in 1..k {
        engine.decode();
        peak = peak.max(engine.state_bytes());
    }
    let decode_s = t1.elapsed().as_secs_f64();
    GenReport {
        prefill_s,
        decode_s,
        tokens: k * prompts.len(),
        peak_state_bytes: peak,
    }
}
