//! Analytic memory ledger: exact byte accounting for generation state at
//! arbitrary model scale (the paper's A100-80GB numbers correspond to
//! `budget` here).  Drives the OOM frontiers in Figures 1.1 / 5.4 / D.11
//! at paper-scale shapes, cross-validated against the engines' measured
//! `state_bytes()` at bench scale (tests below).

use super::shapes::LmShape;

/// Bytes per element (engines run f32; the paper benchmarks fp16 — set 2
/// to reproduce the paper's absolute numbers).
pub const F32: u64 = 4;

/// KV-cache bytes for one sequence at context length t (Transformer).
pub fn kv_cache_bytes(shape: &LmShape, t: usize, elem: u64) -> u64 {
    2 * shape.n_layer as u64 * shape.d_model as u64 * t as u64 * elem
}

/// Gated-signal history bytes for one sequence (conv-mode LCSM).
pub fn conv_cache_bytes(shape: &LmShape, t: usize, elem: u64) -> u64 {
    shape.n_layer as u64 * shape.d_model as u64 * t as u64 * elem
}

/// Recurrent state bytes for one sequence (LaughingHyena): complex modal
/// state per channel plus the short-conv tail — *independent of t*.
pub fn ssm_state_bytes(shape: &LmShape, elem: u64) -> u64 {
    shape.n_layer as u64
        * (2 * shape.d_model as u64 * shape.d_state as u64
            + 3 * shape.d_model as u64 * (shape.short_kw as u64 - 1))
        * elem
}

/// Largest batch that fits a memory budget for a (T, K) generation
/// workload, given per-sequence state at the worst case t = T + K.
pub fn max_batch(per_seq_bytes: u64, weights: u64, budget: u64) -> usize {
    if budget <= weights || per_seq_bytes == 0 {
        return 0;
    }
    ((budget - weights) / per_seq_bytes) as usize
}

/// Approximate weight bytes for a shape.
pub fn weight_bytes(shape: &LmShape, elem: u64) -> u64 {
    shape.params() * elem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::conv_cache::ConvCacheEngine;
    use crate::engine::recurrent::RecurrentEngine;
    use crate::engine::transformer::TransformerEngine;
    use crate::engine::Engine;

    #[test]
    fn ledger_matches_measured_engine_state() {
        let shape = LmShape::bench("nano").unwrap();
        let t = 12;
        // transformer
        let mut tr = TransformerEngine::new(&shape, 1, 1);
        tr.prefill(&[vec![1; t]]);
        assert_eq!(tr.state_bytes(), kv_cache_bytes(&shape, t, F32));
        // conv cache (history only part of state; add short-conv tail)
        let mut cv = ConvCacheEngine::new(&shape, 1, 1);
        cv.prefill(&[vec![1; t]]);
        let sc_tail = (shape.n_layer * 3 * shape.d_model * (shape.short_kw - 1)) as u64 * F32;
        assert_eq!(cv.state_bytes(), conv_cache_bytes(&shape, t, F32) + sc_tail);
        // recurrent: constant
        let mut rc = RecurrentEngine::new(&shape, 1, 1);
        rc.prefill(&[vec![1; t]]);
        assert_eq!(rc.state_bytes(), ssm_state_bytes(&shape, F32));
    }

    #[test]
    fn recurrent_state_beats_kv_cache_at_scale() {
        // the Figure 5.4 gap: at 1.3B/2048 context, KV cache dwarfs the
        // distilled state by orders of magnitude
        let shape = LmShape::paper("1.3b").unwrap();
        let kv = kv_cache_bytes(&shape, 2048, 2);
        let ssm = ssm_state_bytes(&shape, 2);
        assert!(kv > 50 * ssm, "kv {kv} vs ssm {ssm}");
    }

    #[test]
    fn max_batch_ordering_reproduces_fig11_frontier() {
        // under the same budget, LaughingHyena admits far larger batches
        let shape = LmShape::paper("1.3b").unwrap();
        let budget = 80 << 30; // A100 80GB
        let w = weight_bytes(&shape, 2);
        let l = 2048;
        let b_tr = max_batch(kv_cache_bytes(&shape, l, 2), w, budget);
        let b_conv = max_batch(conv_cache_bytes(&shape, l, 2), w, budget);
        let b_lh = max_batch(ssm_state_bytes(&shape, 2), w, budget);
        assert!(b_lh > b_conv && b_conv > b_tr, "{b_lh} {b_conv} {b_tr}");
        assert!(b_lh >= 10 * b_tr, "paper: ~10x larger peak batches");
    }

    #[test]
    fn zero_budget_admits_nothing() {
        let shape = LmShape::paper("125m").unwrap();
        assert_eq!(max_batch(ssm_state_bytes(&shape, 2), weight_bytes(&shape, 2), 0), 0);
    }
}
