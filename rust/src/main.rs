//! `repro` — the Laughing Hyena Distillery launcher.
//!
//! Subcommands:
//!
//! ```text
//! experiment <id>   regenerate a paper table/figure (or 'all')
//! train <tag>       drive an AOT train_step artifact
//! distill           distill synthetic or checkpoint filters, report errors
//! serve             run the serving coordinator demo; with --shards N > 1,
//!                   a sharded cluster (router + N loopback shard servers)
//!                   with optional live migration and drain
//! loadgen           drive a loadgen workload (closed or open loop) against
//!                   an in-process sharded cluster's wire front door and
//!                   write BENCH_load.json
//! info              environment and artifact inventory
//! ```

use anyhow::Result;
use laughing_hyena::cli::Args;
use laughing_hyena::config::{ModelConfig, RawConfig, ServeConfig};
use laughing_hyena::coordinator::server::{spawn, SlotEngine};
use laughing_hyena::data::corpus::Corpus;
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::LmShape;
use laughing_hyena::experiments;
use laughing_hyena::runtime::artifact::Runtime;
use laughing_hyena::runtime::trainer::Trainer;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("experiment") => cmd_experiment(&args),
        Some("train") => cmd_train(&args),
        Some("distill") => cmd_distill(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: repro <experiment|train|distill|serve|info> [args]\n\
                 \n\
                 repro experiment <id>           one of {:?} or 'all'\n\
                 repro train <tag> --steps N     e.g. tag multihyena_small\n\
                 repro distill --order D         distillery over synthetic suites\n\
                 repro serve --requests N        coordinator demo (native engine)\n\
                 repro serve --sessions N --turns T [--session-budget B --spill-dir D]\n\
                 \u{20}                               multi-turn session demo (state resume)\n\
                 repro serve --shards K --sessions N --turns T [--migrate] [--drain I]\n\
                 \u{20}                               sharded cluster demo: router + K loopback\n\
                 \u{20}                               shards, live session migration, drain\n\
                 \u{20}                               [--journal-dir D] write-ahead turn journal:\n\
                 \u{20}                               replayed on start, so a restarted router\n\
                 \u{20}                               resumes every acked turn\n\
                 repro serve --shards K --chaos  kill a shard mid-conversation and show\n\
                 \u{20}                               transcript-mirror resurrection\n\
                 repro loadgen --shards K --sessions N --turns T [--rate R --think-ms M\n\
                 \u{20}                               --prompt P --tokens G --deadline-ms D\n\
                 \u{20}                               --max-inflight F --load-seed S --out PATH]\n\
                 \u{20}                               closed (default) or open-loop (--rate > 0)\n\
                 \u{20}                               load over the wire front door; reports\n\
                 \u{20}                               TTFT/TPOT/e2e percentiles + refusal counts\n\
                 \u{20}                               and writes BENCH_load.json\n\
                 repro info",
                experiments::ALL
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    experiments::run(id, args)
}

fn cmd_train(args: &Args) -> Result<()> {
    let tag = args.positional.get(1).cloned().unwrap_or("multihyena_small".into());
    let steps = args.get_usize("steps", 100);
    let dir = laughing_hyena::experiments::common::require_artifacts()?;
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    let mut tr = Trainer::new(&rt, &dir, &tag)?;
    let mut corpus = Corpus::new(512, 4, args.get_u64("seed", 1234));
    let mask = vec![1.0f32; tr.batch * tr.seq_len];
    for i in 0..steps {
        let (tok, tgt) = corpus.batch(tr.batch, tr.seq_len);
        let loss = tr.step(&tok, &tgt, &mask)?;
        if i % 10 == 0 || i + 1 == steps {
            println!("step {i:>5}  loss {loss:.4}");
        }
    }
    Ok(())
}

fn cmd_distill(args: &Args) -> Result<()> {
    use laughing_hyena::data::filters::{model_filters, Family};
    use laughing_hyena::distill::{DistillConfig, Distillery};
    let order = args.get_usize("order", 16);
    let iters = args.get_usize("iters", 2000);
    let distillery = Distillery {
        order: Some(order),
        fit: DistillConfig { iters, ..Default::default() },
        hankel_window: Some(64),
        ..Default::default()
    };
    for fam in [Family::H3Iir, Family::Hyena, Family::MultiHyena] {
        let filters = model_filters(fam, args.get_usize("filters", 4), 256, 99);
        let r = distillery.distill_all(&filters);
        println!(
            "{:>12}: order {order}, rel err min {:.3e} mean {:.3e} max {:.3e}",
            fam.label(),
            r.min_err(),
            r.mean_err(),
            r.max_err()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg_file = args.get("config");
    let raw = match cfg_file {
        Some(p) => RawConfig::load(p)?,
        None => RawConfig::parse("")?,
    };
    let mut serve_cfg = ServeConfig::from_raw(&raw);
    let _model_cfg = ModelConfig::from_raw(&raw);
    if let Some(dir) = args.get("spill-dir") {
        serve_cfg.session_spill_dir = Some(dir.to_string());
    }
    if let Some(dir) = args.get("journal-dir") {
        serve_cfg.journal_dir = Some(dir.to_string());
    }
    serve_cfg.session_budget =
        args.get_u64("session-budget", serve_cfg.session_budget);
    let n_shards = args.get_usize("shards", 1);
    if n_shards > 1 {
        return cmd_serve_cluster(args, serve_cfg, n_shards);
    }
    let n_requests = args.get_usize("requests", 16);
    let slots = args.get_usize("slots", serve_cfg.max_batch);
    let shape_name = args.get("shape").unwrap_or("nano").to_string();
    let max_new = args.get_usize("tokens", serve_cfg.max_new_tokens.min(16));
    let n_sessions = args.get_usize("sessions", 0);
    let handle = spawn(
        move || {
            let shape = LmShape::bench(&shape_name).expect("shape");
            Box::new(RecurrentEngine::new(&shape, slots, 11)) as Box<dyn SlotEngine>
        },
        serve_cfg,
    );
    let t0 = std::time::Instant::now();
    if n_sessions > 0 {
        // multi-turn session demo: each session runs `--turns` turns, every
        // turn resuming the stored O(1) recurrence state instead of
        // re-prefilling the growing transcript
        let turns = args.get_usize("turns", 4);
        println!(
            "session demo: {n_sessions} sessions x {turns} turns over {slots} slots"
        );
        for t in 0..turns {
            let rxs: Vec<_> = (0..n_sessions)
                .map(|s| {
                    let delta = vec![1 + ((s + t) % 32) as i32; 8];
                    handle.submit_in_session(s as u64, delta, max_new)
                })
                .collect::<std::result::Result<_, _>>()?;
            for (s, rx) in rxs.into_iter().enumerate() {
                let r = rx.recv()?;
                println!(
                    "session {s:>3} turn {t}: {} tokens, ttft {:.1}ms, total {:.1}ms",
                    r.tokens.len(),
                    r.ttft_s * 1e3,
                    r.total_s * 1e3
                );
            }
        }
    } else {
        println!(
            "coordinator demo: {n_requests} requests over {slots} slots (shape {})",
            args.get("shape").unwrap_or("nano")
        );
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| handle.submit(vec![1 + (i % 32) as i32; 16], max_new))
            .collect::<std::result::Result<_, _>>()?;
        for rx in rxs {
            let r = rx.recv()?;
            println!(
                "req {:>3}: {} tokens, ttft {:.1}ms, total {:.1}ms",
                r.id,
                r.tokens.len(),
                r.ttft_s * 1e3,
                r.total_s * 1e3
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", handle.metrics.report());
    println!("wall {wall:.2}s");
    handle.shutdown();
    Ok(())
}

/// The sharded serving demo: a router over `n_shards` in-process shard
/// servers on loopback sockets, fronted by a [`FrontServer`] whose HTTP
/// sibling listener exposes `/metrics`, `/admin` and `/traces` for the
/// demo's lifetime.  Interleaved multi-turn sessions with
/// consistent-hash affinity, an optional live migration mid-conversation
/// (`--migrate`), an optional injected shard kill with transcript-mirror
/// resurrection (`--chaos`), and an optional shard drain at the end
/// (`--drain I`), closing with the per-shard + aggregated health report.
fn cmd_serve_cluster(args: &Args, serve_cfg: ServeConfig, n_shards: usize) -> Result<()> {
    use laughing_hyena::serve::{
        AdminReport, BreakerConfig, Cluster, FaultPlan, FrontConfig, FrontServer,
    };
    let shape_name = args.get_str("shape", "nano");
    let shape = LmShape::bench(shape_name)
        .ok_or_else(|| anyhow::anyhow!("unknown bench shape '{shape_name}'"))?;
    let slots = args.get_usize("slots", serve_cfg.max_batch);
    let max_new = args.get_usize("tokens", serve_cfg.max_new_tokens.min(16));
    let sessions = args.get_usize("sessions", 4);
    let turns = args.get_usize("turns", 3);
    let seed = args.get_u64("seed", 11);
    let migrate = args.has_flag("migrate");
    let chaos = args.has_flag("chaos");
    if chaos && n_shards < 2 {
        anyhow::bail!("--chaos needs at least 2 shards (one must survive the kill)");
    }
    println!(
        "sharded serve demo: {n_shards} shards x {slots} slots (shape {shape_name}), \
         {sessions} sessions x {turns} turns{}{}",
        if migrate { ", with live migration" } else { "" },
        if chaos { ", with an injected shard kill" } else { "" }
    );
    let faults = chaos.then(|| std::sync::Arc::new(FaultPlan::new()));
    // chaos runs pin the breaker cooldown to zero so the revived shard can
    // rejoin (via a half-open probe) within the demo's lifetime
    let breaker_cfg = if chaos {
        BreakerConfig { cooldown: std::time::Duration::ZERO, ..BreakerConfig::default() }
    } else {
        BreakerConfig::default()
    };
    let cluster = Cluster::launch_native_with(
        n_shards,
        &shape,
        slots,
        seed,
        &serve_cfg,
        breaker_cfg,
        faults.clone(),
    )?;
    // hand the router to a front server so the demo cluster is scrapeable
    // while it runs; the demo itself drives turns through the same router
    // lock the front's wire connections use
    let (shards, cluster_router) = cluster.into_parts();
    let bind_host = serve_cfg.bind_addr.clone().unwrap_or_else(|| "127.0.0.1".to_string());
    let front_cfg = FrontConfig {
        profile_sample: serve_cfg.profile_sample,
        ..FrontConfig::default()
    };
    let front = FrontServer::spawn_on(cluster_router, front_cfg, &bind_host)?;
    println!(
        "observability: scrape http://{addr}/metrics (Prometheus text); \
         dashboard at http://{addr}/admin, recent traces at http://{addr}/traces",
        addr = front.http_addr()
    );
    println!(
        "tracing: per-request span timelines at http://{addr}/trace/<id> \
         (the <id> every Done frame carries); liveness http://{addr}/healthz, \
         readiness http://{addr}/readyz",
        addr = front.http_addr()
    );
    if let Some(dir) = &serve_cfg.journal_dir {
        println!(
            "durability: write-ahead turn journal at {dir} — restart with the same \
             --journal-dir and every acked turn replays"
        );
    }
    let router = front.router();
    let t0 = std::time::Instant::now();
    for t in 0..turns {
        for s in 0..sessions {
            let sid = s as u64;
            let delta = vec![1 + ((s + t) % 32) as i32; 6];
            let mut r = router.lock().unwrap();
            let toks = r.submit_in_session(sid, delta, max_new)?;
            println!(
                "session {s:>3} turn {t}: {} tokens on shard {}",
                toks.len(),
                r.shard_of(sid).map(|i| i.to_string()).unwrap_or_default()
            );
        }
        if t == 0 && migrate && sessions > 0 {
            // live-migrate session 0 between turns: the next turn resumes
            // its O(1) state on another shard, bit-identical
            let mut r = router.lock().unwrap();
            let from = r.shard_of(0).unwrap_or(0);
            let to = (from + 1) % n_shards;
            let bytes = r.migrate(0, to)?;
            println!("migrated session 0: shard {from} -> {to} ({bytes} state bytes shipped)");
        }
        if t == 0 && sessions > 0 {
            if let (Some(plan), Some(home)) = (&faults, router.lock().unwrap().shard_of(0)) {
                // kill session 0's home shard between turns: the next
                // turn is resurrected from the router's transcript
                // mirror on a surviving shard, token-identical
                plan.kill(shards[home].addr());
                println!(
                    "chaos: killed shard {home} (session 0's home) — the next turn \
                     resurrects the session from the transcript mirror"
                );
            }
        }
    }
    if let Some(plan) = &faults {
        let mut r = router.lock().unwrap();
        let states: Vec<_> = (0..n_shards).filter_map(|i| r.breaker_state(i)).collect();
        println!("circuit breakers after the kill: {states:?}");
        for s in &shards {
            plan.revive(s.addr());
        }
        let states = r.probe_all();
        println!("revived all shards; circuits after a health probe: {states:?}");
    }
    if let Some(idx) = args.get("drain").and_then(|v| v.parse::<usize>().ok()) {
        let moved = router.lock().unwrap().drain(idx)?;
        println!("drained shard {idx}: migrated {} resident sessions away", moved.len());
    }
    println!("\nper-shard health:\n{}", AdminReport::collect(&mut router.lock().unwrap())?);
    println!("wall {:.2}s", t0.elapsed().as_secs_f64());
    drop(router);
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
    Ok(())
}

/// `repro loadgen`: launch an in-process sharded cluster behind a wire
/// front door, drive the deterministic loadgen workload against it
/// (closed loop by default, open loop with `--rate R` sessions/sec),
/// print client-side latency percentiles + refusal counts, and write the
/// machine-readable `BENCH_load.json` next to the repo root.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use laughing_hyena::loadgen::{self, LoadConfig};
    use laughing_hyena::serve::{BreakerConfig, Cluster, FrontConfig, FrontServer};
    let raw = match args.get("config") {
        Some(p) => RawConfig::load(p)?,
        None => RawConfig::parse("")?,
    };
    let mut serve_cfg = ServeConfig::from_raw(&raw);
    if let Some(dir) = args.get("spill-dir") {
        serve_cfg.session_spill_dir = Some(dir.to_string());
    }
    serve_cfg.session_budget = args.get_u64("session-budget", serve_cfg.session_budget);
    let n_shards = args.get_usize("shards", 2).max(1);
    let slots = args.get_usize("slots", serve_cfg.max_batch);
    let shape_name = args.get_str("shape", "nano");
    let shape = LmShape::bench(shape_name)
        .ok_or_else(|| anyhow::anyhow!("unknown bench shape '{shape_name}'"))?;
    let seed = args.get_u64("seed", 11);
    let cfg = LoadConfig {
        sessions: args.get_usize("sessions", 32),
        turns: args.get_usize("turns", 3),
        rate_hz: args.get_f64("rate", 0.0),
        think_ms: args.get_u64("think-ms", 0),
        prompt_len: args.get_usize("prompt", 8),
        max_new: args.get_usize("tokens", 8),
        deadline_ms: args.get_u64("deadline-ms", 0) as u32,
        seed: args.get_u64("load-seed", 7),
    };
    let cluster = Cluster::launch_native_with(
        n_shards,
        &shape,
        slots,
        seed,
        &serve_cfg,
        BreakerConfig::default(),
        None,
    )?;
    let (shards, cluster_router) = cluster.into_parts();
    let front_cfg = FrontConfig {
        max_inflight: args.get_usize("max-inflight", 32),
        profile_sample: serve_cfg.profile_sample,
        ..FrontConfig::default()
    };
    let front = FrontServer::spawn(cluster_router, front_cfg)?;
    println!(
        "loadgen: {} sessions x ~{} turns, {} mode, {n_shards} shards x {slots} slots \
         (shape {shape_name}), front door at {}",
        cfg.sessions,
        cfg.turns,
        if cfg.rate_hz > 0.0 {
            format!("open loop at {:.1} sessions/s", cfg.rate_hz)
        } else {
            "closed loop".to_string()
        },
        front.addr()
    );
    let report = loadgen::run(front.addr(), &cfg);
    print!("{}", report.summary());
    let cluster_snap = front.router().lock().unwrap().cluster_metrics();
    let front_snap = front.front_metrics();
    let doc = loadgen::bench_doc(&cfg, &report, &cluster_snap, &front_snap);
    let out = args
        .get_str("out", concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_load.json"))
        .to_string();
    doc.save(&out)?;
    println!("wrote {out}");
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
    Ok(())
}

fn cmd_info(_args: &Args) -> Result<()> {
    println!("laughing-hyena repro — three-layer Rust + JAX + Pallas stack");
    let dir = laughing_hyena::experiments::common::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    if dir.exists() {
        let mut n_hlo = 0;
        let mut n_ck = 0;
        for e in std::fs::read_dir(&dir)? {
            let name = e?.file_name().to_string_lossy().to_string();
            if name.ends_with(".hlo.txt") {
                n_hlo += 1;
            }
            if name.ends_with(".bin") {
                n_ck += 1;
            }
        }
        println!("  {n_hlo} HLO artifacts, {n_ck} checkpoints");
    } else {
        println!("  (missing — run `make artifacts`)");
    }
    match Runtime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    Ok(())
}
