//! Named metric registry: counters, gauges, and histograms keyed by
//! stable metric names, with exact snapshot merging and Prometheus text
//! exposition.
//!
//! The registry is deliberately schema-first: every metric name the
//! codebase emits is declared once in [`SCHEMA`] with its kind and help
//! text, and a unit test fails if the table ever carries a duplicate.
//! At runtime the registry is forgiving instead of panicking — an
//! operation against a name that already holds a different kind is
//! dropped and the name is remembered in a conflict set, so a
//! mis-registered metric shows up in tests (and in `/metrics` as a
//! `lh_metric_conflicts` gauge) without ever taking down a serving
//! process.
//!
//! Keys may carry one Prometheus label inline, e.g.
//! `lh_route_seconds{shard="0"}`: everything before the first `{` is the
//! metric family name (used for `# TYPE` lines and schema lookup), the
//! braced remainder is emitted verbatim as the label set. Snapshots are
//! `BTreeMap`-backed so iteration — and therefore the rendered text —
//! is deterministic.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use crate::obs::hist::{bucket_upper, Hist, BUCKETS};

/// The kind of a metric family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone event count; rendered as a Prometheus counter.
    Counter,
    /// Point-in-time level; rendered as a Prometheus gauge.
    Gauge,
    /// Log-bucketed latency distribution; rendered as a Prometheus
    /// histogram (`_bucket`/`_sum`/`_count` series).
    Hist,
}

impl MetricKind {
    fn prom_type(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Hist => "histogram",
        }
    }
}

/// One metric's current value.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(u64),
    Hist(Hist),
}

impl MetricValue {
    pub fn kind(&self) -> MetricKind {
        match self {
            MetricValue::Counter(_) => MetricKind::Counter,
            MetricValue::Gauge(_) => MetricKind::Gauge,
            MetricValue::Hist(_) => MetricKind::Hist,
        }
    }
}

/// Every metric family the crate emits: `(family name, kind, help)`.
/// One row per name — `schema_is_duplicate_free` enforces it — so two
/// call sites can never ship the same name with different kinds.
pub const SCHEMA: &[(&str, MetricKind, &str)] = &[
    // coordinator (per shard, merged by the router)
    ("lh_requests_total", MetricKind::Counter, "requests accepted by the coordinator"),
    ("lh_requests_done_total", MetricKind::Counter, "requests fully generated"),
    ("lh_tokens_generated_total", MetricKind::Counter, "tokens emitted by the decode loop"),
    ("lh_prefills_total", MetricKind::Counter, "prompt prefill jobs run"),
    ("lh_decode_steps_total", MetricKind::Counter, "batched decode steps run"),
    ("lh_queue_depth", MetricKind::Gauge, "requests waiting for a slot right now"),
    ("lh_queue_peak", MetricKind::Gauge, "deepest admission queue seen"),
    ("lh_ttft_seconds", MetricKind::Hist, "enqueue to first token"),
    ("lh_e2e_seconds", MetricKind::Hist, "enqueue to final token"),
    ("lh_queue_wait_seconds", MetricKind::Hist, "enqueue to slot admission"),
    ("lh_tpot_seconds", MetricKind::Hist, "per-request mean time per output token after the first"),
    ("lh_prefill_seconds", MetricKind::Hist, "wall time of each prefill batch"),
    // session store (per shard, merged by the router)
    ("lh_session_hits_total", MetricKind::Counter, "turns resumed from stored O(1) state"),
    ("lh_session_misses_total", MetricKind::Counter, "turns that re-prefilled a lost state"),
    ("lh_prefill_tokens_saved_total", MetricKind::Counter, "prefill tokens skipped via state resume"),
    ("lh_sessions_resident", MetricKind::Gauge, "sessions RAM-resident in the store"),
    ("lh_session_bytes", MetricKind::Gauge, "bytes resident in the session store"),
    ("lh_session_evictions_total", MetricKind::Counter, "session-store evictions"),
    ("lh_session_spills_total", MetricKind::Counter, "evictions persisted to the spill dir"),
    ("lh_session_ttl_evictions_total", MetricKind::Counter, "idle sessions fully forgotten by the TTL sweep"),
    ("lh_spill_bytes", MetricKind::Gauge, "live bytes held by the disk spill tier"),
    ("lh_spill_evictions_total", MetricKind::Counter, "sessions dropped by the spill tier to honor its byte cap"),
    ("lh_spill_compactions_total", MetricKind::Counter, "spill segments compacted"),
    ("lh_shed_deadline_total", MetricKind::Counter, "queued requests shed past their deadline budget"),
    ("lh_shed_overload_total", MetricKind::Counter, "requests refused at a full admission queue"),
    // engine hot-path profiling (sampled; per shard, merged by the router)
    ("lh_engine_profiled_total", MetricKind::Counter, "requests whose engine hot path was stage-profiled"),
    ("lh_engine_short_conv_seconds", MetricKind::Hist, "per profiled request: short-conv stage wall time"),
    ("lh_engine_modal_sweep_seconds", MetricKind::Hist, "per profiled request: modal recurrence sweep wall time"),
    ("lh_engine_qkv_seconds", MetricKind::Hist, "per profiled request: qkv projection GEMV wall time"),
    ("lh_engine_out_proj_seconds", MetricKind::Hist, "per profiled request: output projection GEMV wall time"),
    ("lh_engine_mlp_seconds", MetricKind::Hist, "per profiled request: MLP GEMV wall time"),
    ("lh_engine_lm_head_seconds", MetricKind::Hist, "per profiled request: LM-head GEMV wall time"),
    // router
    ("lh_route_seconds", MetricKind::Hist, "router-observed round trip per routed turn"),
    ("lh_migration_attempts_total", MetricKind::Counter, "live session migrations started"),
    ("lh_migration_commits_total", MetricKind::Counter, "migrations committed on the target"),
    ("lh_migration_aborts_total", MetricKind::Counter, "migrations rolled back to the source"),
    ("lh_resurrections_total", MetricKind::Counter, "sessions rebuilt from the transcript mirror"),
    ("lh_retries_total", MetricKind::Counter, "router retries spent from per-request retry budgets"),
    ("lh_breaker_state", MetricKind::Gauge, "circuit state per shard: 0 closed, 1 half-open, 2 open"),
    ("lh_breaker_opened_total", MetricKind::Counter, "circuit transitions into open"),
    ("lh_breaker_half_opened_total", MetricKind::Counter, "open circuits that admitted a probe"),
    ("lh_breaker_closed_total", MetricKind::Counter, "circuits re-closed by a success"),
    ("lh_fault_hits_total", MetricKind::Counter, "fault-injection rules fired (chaos runs)"),
    ("lh_scrape_errors_total", MetricKind::Counter, "shards that failed to answer a metrics pull"),
    // front door
    ("lh_front_requests_total", MetricKind::Counter, "generation requests reaching the front door"),
    ("lh_front_over_capacity_total", MetricKind::Counter, "requests refused by the in-flight gate"),
    ("lh_front_errors_total", MetricKind::Counter, "generation relays that ended in an error frame"),
    ("lh_front_in_flight", MetricKind::Gauge, "generations currently relayed by the front door"),
    ("lh_front_shed_deadline_total", MetricKind::Counter, "queued front-door requests shed when their deadline budget ran out"),
    ("lh_front_queue_wait_seconds", MetricKind::Hist, "time a deadline-budgeted request waited in the front admission queue"),
    ("lh_stream_token_seconds", MetricKind::Hist, "front-door inter-token gap on streamed replies"),
    // write-ahead turn journal (router-side crash durability)
    ("lh_journal_appended_total", MetricKind::Counter, "journal records durably appended"),
    ("lh_journal_replayed_total", MetricKind::Counter, "journal records applied during cold-start replay"),
    ("lh_journal_deduped_total", MetricKind::Counter, "duplicate turns absorbed by the journal's dedup window"),
    ("lh_journal_truncated_tails_total", MetricKind::Counter, "torn journal tails truncated at open"),
    ("lh_journal_compactions_total", MetricKind::Counter, "journal live-ratio compactions"),
    ("lh_journal_append_errors_total", MetricKind::Counter, "journal appends that failed (turn still served)"),
    ("lh_metric_conflicts", MetricKind::Gauge, "metric names used with conflicting kinds"),
];

/// Kind declared in [`SCHEMA`] for a family name, if any.
pub fn schema_kind(family: &str) -> Option<MetricKind> {
    SCHEMA.iter().find(|(n, _, _)| *n == family).map(|(_, k, _)| *k)
}

fn schema_help(family: &str) -> Option<&'static str> {
    SCHEMA.iter().find(|(n, _, _)| *n == family).map(|(_, _, h)| *h)
}

/// Split a key into `(family, labels)`: `lh_x{shard="0"}` →
/// `("lh_x", Some("shard=\"0\""))`.
fn split_key(key: &str) -> (&str, Option<&str>) {
    match key.find('{') {
        Some(i) => {
            let rest = &key[i + 1..];
            (&key[..i], Some(rest.strip_suffix('}').unwrap_or(rest)))
        }
        None => (key, None),
    }
}

/// A point-in-time set of named metric values. Mergeable: counters and
/// gauges add, histograms merge bucket-exactly, so per-shard snapshots
/// sum into a cluster snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub entries: BTreeMap<String, MetricValue>,
}

impl Snapshot {
    /// Add `delta` to a counter. Returns `false` (and changes nothing)
    /// if the name already holds a non-counter.
    pub fn add_counter(&mut self, name: &str, delta: u64) -> bool {
        match self.entries.get_mut(name) {
            None => {
                self.entries.insert(name.to_string(), MetricValue::Counter(delta));
                true
            }
            Some(MetricValue::Counter(c)) => {
                *c += delta;
                true
            }
            Some(_) => false,
        }
    }

    /// Set a gauge to `v`. Returns `false` on a kind conflict.
    pub fn set_gauge(&mut self, name: &str, v: u64) -> bool {
        match self.entries.get_mut(name) {
            None => {
                self.entries.insert(name.to_string(), MetricValue::Gauge(v));
                true
            }
            Some(MetricValue::Gauge(g)) => {
                *g = v;
                true
            }
            Some(_) => false,
        }
    }

    /// Record a latency sample into a histogram. Returns `false` on a
    /// kind conflict.
    pub fn observe(&mut self, name: &str, seconds: f64) -> bool {
        match self.entries.get_mut(name) {
            None => {
                let mut h = Hist::new();
                h.record(seconds);
                self.entries.insert(name.to_string(), MetricValue::Hist(h));
                true
            }
            Some(MetricValue::Hist(h)) => {
                h.record(seconds);
                true
            }
            Some(_) => false,
        }
    }

    /// Merge one entry: counters add, gauges add (so per-shard levels
    /// sum into a cluster level), histograms merge. Returns `false` on
    /// a kind conflict.
    pub fn merge_entry(&mut self, name: &str, v: MetricValue) -> bool {
        match (self.entries.get_mut(name), v) {
            (None, v) => {
                self.entries.insert(name.to_string(), v);
                true
            }
            (Some(MetricValue::Counter(a)), MetricValue::Counter(b)) => {
                *a += b;
                true
            }
            (Some(MetricValue::Gauge(a)), MetricValue::Gauge(b)) => {
                *a += b;
                true
            }
            (Some(MetricValue::Hist(a)), MetricValue::Hist(b)) => {
                a.merge(&b);
                true
            }
            _ => false,
        }
    }

    /// Merge a whole snapshot; returns the names that conflicted (and
    /// were skipped).
    pub fn merge(&mut self, other: &Snapshot) -> Vec<String> {
        let mut conflicts = Vec::new();
        for (name, v) in &other.entries {
            if !self.merge_entry(name, v.clone()) {
                conflicts.push(name.clone());
            }
        }
        conflicts
    }
}

#[derive(Default)]
struct RegistryInner {
    snap: Snapshot,
    conflicts: BTreeSet<String>,
}

/// Thread-safe live registry: the mutable front end over a [`Snapshot`].
/// Kind conflicts never panic; they are recorded and surfaced via
/// [`Registry::conflicts`] and the `lh_metric_conflicts` gauge.
#[derive(Default)]
pub struct Registry(Mutex<RegistryInner>);

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, delta: u64) {
        let mut r = self.0.lock().unwrap();
        if !r.snap.add_counter(name, delta) {
            r.conflicts.insert(name.to_string());
        }
    }

    pub fn set_gauge(&self, name: &str, v: u64) {
        let mut r = self.0.lock().unwrap();
        if !r.snap.set_gauge(name, v) {
            r.conflicts.insert(name.to_string());
        }
    }

    pub fn observe(&self, name: &str, seconds: f64) {
        let mut r = self.0.lock().unwrap();
        if !r.snap.observe(name, seconds) {
            r.conflicts.insert(name.to_string());
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let r = self.0.lock().unwrap();
        let mut s = r.snap.clone();
        if !r.conflicts.is_empty() {
            s.set_gauge("lh_metric_conflicts", r.conflicts.len() as u64);
        }
        s
    }

    /// Names that were ever used with two different kinds.
    pub fn conflicts(&self) -> Vec<String> {
        self.0.lock().unwrap().conflicts.iter().cloned().collect()
    }
}

/// Render a snapshot in the Prometheus text exposition format (v0.0.4):
/// `# HELP`/`# TYPE` per family, `_bucket{le=...}`/`_sum`/`_count`
/// series for histograms, cumulative bucket counts, `+Inf` last.
/// Deterministic for a given snapshot.
pub fn render_prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    let mut typed: BTreeSet<String> = BTreeSet::new();
    for (key, val) in &snap.entries {
        let (family, labels) = split_key(key);
        if typed.insert(family.to_string()) {
            if let Some(help) = schema_help(family) {
                out.push_str(&format!("# HELP {family} {help}\n"));
            }
            out.push_str(&format!("# TYPE {family} {}\n", val.kind().prom_type()));
        }
        let label_sample = |extra: &str| -> String {
            match (labels, extra.is_empty()) {
                (Some(l), true) => format!("{{{l}}}"),
                (Some(l), false) => format!("{{{l},{extra}}}"),
                (None, true) => String::new(),
                (None, false) => format!("{{{extra}}}"),
            }
        };
        match val {
            MetricValue::Counter(c) | MetricValue::Gauge(c) => {
                out.push_str(&format!("{family}{} {c}\n", label_sample("")));
            }
            MetricValue::Hist(h) => {
                let mut cum = 0u64;
                for (i, &c) in h.bucket_counts().iter().enumerate() {
                    cum += c;
                    let le = if i + 1 >= BUCKETS {
                        "+Inf".to_string()
                    } else {
                        format!("{}", bucket_upper(i))
                    };
                    out.push_str(&format!(
                        "{family}_bucket{} {cum}\n",
                        label_sample(&format!("le=\"{le}\""))
                    ));
                }
                out.push_str(&format!("{family}_sum{} {}\n", label_sample(""), h.sum()));
                out.push_str(&format!("{family}_count{} {}\n", label_sample(""), h.count()));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_is_duplicate_free() {
        // the registry-uniqueness gate: two call sites can only collide
        // on a name by adding a duplicate row here, which this rejects
        let mut seen = BTreeSet::new();
        for (name, _, help) in SCHEMA {
            assert!(seen.insert(*name), "metric name declared twice in SCHEMA: {name}");
            assert!(!help.is_empty(), "empty help for {name}");
            assert!(name.starts_with("lh_"), "metric outside the lh_ namespace: {name}");
        }
    }

    #[test]
    fn kind_conflicts_are_detected_not_panics() {
        let r = Registry::new();
        r.inc("lh_requests_total", 1);
        // same name, different kind: dropped and remembered
        r.observe("lh_requests_total", 0.5);
        assert_eq!(r.conflicts(), vec!["lh_requests_total".to_string()]);
        // the original counter survives untouched, and the conflict is
        // itself visible as a gauge in the snapshot
        let s = r.snapshot();
        assert_eq!(s.entries.get("lh_requests_total"), Some(&MetricValue::Counter(1)));
        assert_eq!(s.entries.get("lh_metric_conflicts"), Some(&MetricValue::Gauge(1)));
    }

    #[test]
    fn merge_is_exact_across_snapshots() {
        let mut a = Snapshot::default();
        a.add_counter("lh_requests_total", 3);
        a.set_gauge("lh_sessions_resident", 2);
        a.observe("lh_ttft_seconds", 0.01);
        a.observe("lh_ttft_seconds", 0.02);
        let mut b = Snapshot::default();
        b.add_counter("lh_requests_total", 4);
        b.set_gauge("lh_sessions_resident", 5);
        b.observe("lh_ttft_seconds", 0.04);
        let mut total = Snapshot::default();
        let conflicts = total.merge(&a);
        assert!(conflicts.is_empty());
        let conflicts = total.merge(&b);
        assert!(conflicts.is_empty());
        assert_eq!(
            total.entries.get("lh_requests_total"),
            Some(&MetricValue::Counter(7))
        );
        assert_eq!(
            total.entries.get("lh_sessions_resident"),
            Some(&MetricValue::Gauge(7))
        );
        match total.entries.get("lh_ttft_seconds") {
            Some(MetricValue::Hist(h)) => assert_eq!(h.count(), 3),
            other => panic!("expected hist, got {other:?}"),
        }
    }

    #[test]
    fn prometheus_rendering_matches_golden_text() {
        let mut s = Snapshot::default();
        s.add_counter("lh_requests_total", 7);
        s.set_gauge("lh_queue_depth", 2);
        let mut h = Hist::new();
        h.record(0.25); // mid-grid bucket
        h.record(1e9); // overflow bucket
        s.entries.insert("lh_ttft_seconds".into(), MetricValue::Hist(h));
        let text = render_prometheus(&s);
        // spot-check the exact exposition lines (BTreeMap order: depth,
        // requests, ttft)
        assert!(text.starts_with("# HELP lh_queue_depth "), "{text}");
        assert!(text.contains("# TYPE lh_queue_depth gauge\nlh_queue_depth 2\n"), "{text}");
        assert!(
            text.contains("# TYPE lh_requests_total counter\nlh_requests_total 7\n"),
            "{text}"
        );
        assert!(text.contains("# TYPE lh_ttft_seconds histogram\n"), "{text}");
        // cumulative buckets: 0 until the 0.25 sample's bucket, then 1
        // until +Inf picks up the overflow sample
        assert!(text.contains("lh_ttft_seconds_bucket{le=\"0.00001\"} 0\n"), "{text}");
        assert!(text.contains("lh_ttft_seconds_bucket{le=\"+Inf\"} 2\n"), "{text}");
        // 1e9 + 0.25 is exactly representable, so the sum line is stable
        assert!(text.contains("lh_ttft_seconds_sum 1000000000.25\n"), "{text}");
        assert!(text.contains("lh_ttft_seconds_count 2\n"), "{text}");
        // rendering is deterministic
        assert_eq!(text, render_prometheus(&s));
    }

    #[test]
    fn labeled_keys_render_family_type_once() {
        let mut s = Snapshot::default();
        s.set_gauge("lh_breaker_state{shard=\"0\"}", 0);
        s.set_gauge("lh_breaker_state{shard=\"1\"}", 2);
        s.observe("lh_route_seconds{shard=\"0\"}", 0.02);
        let text = render_prometheus(&s);
        assert_eq!(text.matches("# TYPE lh_breaker_state gauge").count(), 1, "{text}");
        assert!(text.contains("lh_breaker_state{shard=\"0\"} 0\n"), "{text}");
        assert!(text.contains("lh_breaker_state{shard=\"1\"} 2\n"), "{text}");
        assert!(text.contains("lh_route_seconds_bucket{shard=\"0\",le=\"+Inf\"} 1\n"), "{text}");
        assert!(text.contains("lh_route_seconds_count{shard=\"0\"} 1\n"), "{text}");
    }

    #[test]
    fn all_schema_kinds_accept_their_op() {
        // every declared family accepts the operation its kind implies,
        // so instrumentation sites can be checked against SCHEMA
        let r = Registry::new();
        for (name, kind, _) in SCHEMA {
            match kind {
                MetricKind::Counter => r.inc(name, 1),
                MetricKind::Gauge => r.set_gauge(name, 1),
                MetricKind::Hist => r.observe(name, 0.001),
            }
        }
        assert!(r.conflicts().is_empty());
        assert_eq!(r.snapshot().entries.len(), SCHEMA.len());
    }
}
