//! Dependency-free observability core for the serving stack.
//!
//! Three pieces, threaded through every serving layer:
//!
//! * [`hist`] — a mergeable fixed-bucket log-spaced latency histogram:
//!   bounded memory per metric, p50/p90/p99 extraction, and *exact*
//!   merge so per-shard histograms sum into cluster histograms.
//! * [`registry`] — named counters/gauges/histograms behind stable
//!   `lh_*` metric names (declared once in [`registry::SCHEMA`]),
//!   snapshotable, mergeable, and renderable as Prometheus text.
//! * [`trace`] — per-request distributed trace records: named spans
//!   (durations + hop-relative offsets, clock-skew-immune) grouped into
//!   per-hop reports and joined across front → router → shard →
//!   coordinator → engine, held in a bounded ring and rendered as JSON
//!   for `GET /traces` and `GET /trace/<id>`.
//!
//! The flow: each shard's coordinator records into its own counters and
//! histograms; a `Metrics` wire frame pulls a shard's snapshot to the
//! router, which merges all shards exactly and folds in its own
//! routing/breaker/migration metrics; the front door serves the merged
//! snapshot at `GET /metrics` (Prometheus text), a human dashboard at
//! `GET /admin`, and recent traces at `GET /traces`.

pub mod hist;
pub mod registry;
pub mod trace;

pub use hist::{bucket_upper, Hist, BUCKETS};
pub use registry::{render_prometheus, MetricKind, MetricValue, Registry, Snapshot, SCHEMA};
pub use trace::{HopReport, Span, TraceRecord, TraceRing, DEFAULT_TRACE_CAP};
