//! Fixed-bucket log-spaced latency histogram with exact merge.
//!
//! Every latency the serving stack records (TTFT, per-token TPOT, queue
//! wait, prefill time, route round trips) lands in a [`Hist`]: a fixed
//! array of [`BUCKETS`] counters whose upper edges grow geometrically
//! (factor √2) from [`LOWEST`] seconds. Memory is bounded regardless of
//! how many requests are observed — this replaces the unbounded
//! `Vec<f64>` latency reservoirs the coordinator used to keep — and two
//! histograms recorded on different shards merge *exactly*: bucket
//! counts, totals, and sums are plain additions, never re-sampling, so
//! the router can sum per-shard histograms into one cluster histogram
//! whose quantiles are as sharp as any single shard's.
//!
//! Quantiles are read by a cumulative walk and resolve to the target
//! bucket's upper edge: the reported p99 is an upper bound that is tight
//! to within one bucket width (a factor of √2 ≈ 1.41). The bucket edges
//! are a compile-time constant of this module, identical on every shard
//! and on the router, which is what makes the merge well-defined.

/// Number of buckets. With √2 growth from [`LOWEST`] the finite edges
/// span 10 µs … ~1342 s (2^27 × 10 µs) before the overflow bucket; 56
/// `u64` counters keep a histogram under half a kilobyte.
pub const BUCKETS: usize = 56;

/// Upper edge of bucket 0, in seconds (10 µs).
const LOWEST: f64 = 1e-5;

/// Geometric growth factor between consecutive bucket upper edges.
const GROWTH: f64 = std::f64::consts::SQRT_2;

/// Upper edge (in seconds) of bucket `i`. The last bucket is the
/// overflow bucket and reports `+∞`. Computed by repeated
/// multiplication so every caller (bucketing, quantiles, Prometheus
/// rendering) sees bit-identical edges.
pub fn bucket_upper(i: usize) -> f64 {
    if i + 1 >= BUCKETS {
        return f64::INFINITY;
    }
    let mut u = LOWEST;
    for _ in 0..i {
        u *= GROWTH;
    }
    u
}

/// Bucket index for a sample. Zero, negative, and NaN samples clamp
/// into bucket 0; anything above the top finite edge lands in the
/// overflow bucket.
fn bucket_of(v: f64) -> usize {
    if !(v > 0.0) {
        return 0;
    }
    let mut upper = LOWEST;
    for i in 0..BUCKETS - 1 {
        if v <= upper {
            return i;
        }
        upper *= GROWTH;
    }
    BUCKETS - 1
}

/// Representative value reported for a quantile landing in bucket `i`:
/// the bucket's upper edge, except the overflow bucket, which reports
/// its (finite) lower edge so quantiles never return infinity.
fn representative(i: usize) -> f64 {
    if i + 1 >= BUCKETS {
        let mut u = LOWEST;
        for _ in 0..BUCKETS - 2 {
            u *= GROWTH;
        }
        u
    } else {
        bucket_upper(i)
    }
}

/// A mergeable latency histogram over the fixed log-spaced bucket grid.
#[derive(Clone, Debug, PartialEq)]
pub struct Hist {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { counts: [0; BUCKETS], count: 0, sum: 0.0 }
    }
}

impl Hist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample, in seconds.
    pub fn record(&mut self, seconds: f64) {
        self.counts[bucket_of(seconds)] += 1;
        self.count += 1;
        if seconds.is_finite() {
            self.sum += seconds.max(0.0);
        }
    }

    /// Fold another histogram into this one. Exact: per-bucket counts
    /// and the total count add as integers (the sum adds as a float, so
    /// it is exact up to addition order).
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded samples, in seconds.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Quantile `q` in `[0, 1]`: the upper edge of the first bucket at
    /// which the cumulative count reaches `ceil(q · count)`. Returns
    /// `0.0` on an empty histogram (matching what the old reservoir
    /// percentile reported before any traffic).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return representative(i);
            }
        }
        representative(BUCKETS - 1)
    }

    /// Raw per-bucket counts, for wire encoding and rendering.
    pub fn bucket_counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }

    /// Rebuild a histogram from wire-decoded parts.
    pub fn from_raw(counts: [u64; BUCKETS], count: u64, sum: f64) -> Hist {
        Hist { counts, count, sum }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_is_bounded_and_small() {
        // the whole point of the satellite fix: a histogram's footprint
        // is a compile-time constant, not a function of traffic
        assert!(std::mem::size_of::<Hist>() <= 512);
        let mut h = Hist::new();
        for i in 0..100_000 {
            h.record(1e-4 * (1 + i % 97) as f64);
        }
        assert_eq!(h.count(), 100_000);
    }

    #[test]
    fn quantile_brackets_the_true_value_within_one_bucket() {
        let mut h = Hist::new();
        for _ in 0..1000 {
            h.record(0.010);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        // conservative upper bound, tight to within the √2 growth factor
        assert!(p50 >= 0.010 && p50 <= 0.010 * GROWTH * 1.0001, "{p50}");
        assert_eq!(p50, p99);
    }

    #[test]
    fn outliers_clamp_instead_of_panicking() {
        let mut h = Hist::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(f64::NAN);
        h.record(1e12);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 5);
        assert_eq!(h.bucket_counts()[0], 3);
        assert_eq!(h.bucket_counts()[BUCKETS - 1], 2);
        // sum skips non-finite and negative values
        assert_eq!(h.sum(), 1e12);
        assert!(h.quantile(1.0).is_finite());
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = Hist::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn edges_are_monotone_and_shared() {
        let mut prev = 0.0;
        for i in 0..BUCKETS - 1 {
            let u = bucket_upper(i);
            assert!(u > prev, "bucket {i}: {u} <= {prev}");
            prev = u;
        }
        assert!(bucket_upper(BUCKETS - 1).is_infinite());
    }

    #[test]
    fn merge_is_exact() {
        // merging shard histograms must equal one histogram that saw the
        // concatenated stream: identical bucket counts and totals
        crate::util::prop::check("hist merge is exact", 64, |rng| {
            let mut a = Hist::new();
            let mut b = Hist::new();
            let mut whole = Hist::new();
            for _ in 0..rng.below(200) {
                let v = rng.uniform() * 10.0;
                a.record(v);
                whole.record(v);
            }
            for _ in 0..rng.below(200) {
                let v = rng.uniform() * 0.01;
                b.record(v);
                whole.record(v);
            }
            let mut merged = a.clone();
            merged.merge(&b);
            if merged.bucket_counts() != whole.bucket_counts() {
                return Err("bucket counts differ".into());
            }
            if merged.count() != whole.count() {
                return Err("totals differ".into());
            }
            let ds = (merged.sum() - whole.sum()).abs();
            if ds > 1e-9 * (1.0 + whole.sum().abs()) {
                return Err(format!("sums differ by {ds}"));
            }
            for q in [0.5, 0.9, 0.99] {
                if merged.quantile(q) != whole.quantile(q) {
                    return Err(format!("q{q} differs"));
                }
            }
            Ok(())
        });
    }
}
