//! Per-request trace records in a bounded ring.
//!
//! A [`Trace`] pins down where one request's latency went as stage
//! offsets from its enqueue instant: queue wait until admission, the
//! prefill batch it rode (if it could not resume a stored state), the
//! first emitted token, and completion. The coordinator pushes one
//! record per retired request into a [`TraceRing`]; the front door
//! keeps its own ring of relayed turns. Rings are fixed-capacity
//! `VecDeque`s — the observability layer never holds unbounded
//! per-request memory — and render as JSON lines for `GET /traces`.

use std::collections::VecDeque;
use std::sync::Mutex;

/// One request's stage timeline, offsets in µs from enqueue. A stage
/// that did not happen (e.g. prefill on a state-resume turn) is 0.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub id: u64,
    pub session: Option<u64>,
    /// Enqueue → slot admission (queue wait).
    pub admit_us: u64,
    /// Enqueue → end of the prefill batch that processed this prompt;
    /// 0 when the turn resumed a stored state and skipped prefill.
    pub prefill_us: u64,
    /// Enqueue → first token emitted.
    pub first_token_us: u64,
    /// Enqueue → final token (end-to-end latency).
    pub done_us: u64,
    /// Tokens generated.
    pub tokens: u32,
    /// False when the request ended in an error instead of a reply.
    pub ok: bool,
}

impl Trace {
    /// One JSON object, no trailing newline. Field order is fixed so
    /// the output is line-diffable.
    pub fn to_json(&self) -> String {
        let session = match self.session {
            Some(s) => s.to_string(),
            None => "null".to_string(),
        };
        format!(
            "{{\"id\":{},\"session\":{},\"admit_us\":{},\"prefill_us\":{},\
             \"first_token_us\":{},\"done_us\":{},\"tokens\":{},\"ok\":{}}}",
            self.id,
            session,
            self.admit_us,
            self.prefill_us,
            self.first_token_us,
            self.done_us,
            self.tokens,
            self.ok
        )
    }
}

/// Capacity of a ring unless the caller picks one: enough recent
/// context to debug a latency spike, small enough to never matter.
pub const DEFAULT_TRACE_CAP: usize = 256;

/// Bounded ring of recent traces, oldest evicted first.
pub struct TraceRing {
    inner: Mutex<VecDeque<Trace>>,
    cap: usize,
}

impl Default for TraceRing {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_TRACE_CAP)
    }
}

impl TraceRing {
    pub fn with_capacity(cap: usize) -> Self {
        TraceRing { inner: Mutex::new(VecDeque::with_capacity(cap.max(1))), cap: cap.max(1) }
    }

    pub fn push(&self, t: Trace) {
        let mut r = self.inner.lock().unwrap();
        if r.len() == self.cap {
            r.pop_front();
        }
        r.push_back(t);
    }

    /// Most recent traces, oldest first.
    pub fn recent(&self) -> Vec<Trace> {
        self.inner.lock().unwrap().iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// JSON-lines rendering for `GET /traces`: one object per line,
    /// oldest first, trailing newline when non-empty.
    pub fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for t in self.inner.lock().unwrap().iter() {
            out.push_str(&t.to_json());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_fifo() {
        let ring = TraceRing::with_capacity(3);
        for i in 0..10u64 {
            ring.push(Trace { id: i, ok: true, ..Trace::default() });
        }
        let recent = ring.recent();
        assert_eq!(recent.len(), 3);
        assert_eq!(
            recent.iter().map(|t| t.id).collect::<Vec<_>>(),
            vec![7, 8, 9],
            "oldest evicted first"
        );
    }

    #[test]
    fn json_lines_are_stable() {
        let ring = TraceRing::with_capacity(8);
        ring.push(Trace {
            id: 1,
            session: Some(42),
            admit_us: 10,
            prefill_us: 200,
            first_token_us: 250,
            done_us: 900,
            tokens: 8,
            ok: true,
        });
        ring.push(Trace { id: 2, ok: false, ..Trace::default() });
        assert_eq!(
            ring.to_json_lines(),
            "{\"id\":1,\"session\":42,\"admit_us\":10,\"prefill_us\":200,\
             \"first_token_us\":250,\"done_us\":900,\"tokens\":8,\"ok\":true}\n\
             {\"id\":2,\"session\":null,\"admit_us\":0,\"prefill_us\":0,\
             \"first_token_us\":0,\"done_us\":0,\"tokens\":0,\"ok\":false}\n"
        );
    }

    #[test]
    fn empty_ring_renders_empty() {
        let ring = TraceRing::default();
        assert!(ring.is_empty());
        assert_eq!(ring.to_json_lines(), "");
    }
}
