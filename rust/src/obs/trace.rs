//! Per-request distributed trace records in a bounded ring.
//!
//! One request that crosses the serving stack leaves one
//! [`TraceRecord`]: a list of [`HopReport`]s (front, router, shard,
//! coordinator, engine), each carrying named [`Span`]s.  Spans are
//! **durations plus offsets relative to their hop's own start** — never
//! absolute timestamps — so reports taken on different machines join
//! into one timeline without any clock-synchronisation assumption, the
//! same scheme the wire protocol already uses for `deadline_ms`
//! budgets.
//!
//! A stage that did not happen (e.g. prefill on a state-resume turn) is
//! simply **absent** from the hop's span list — unlike the old flat
//! fixed-field record, where "offset 0" was ambiguous between "happened
//! instantly" and "skipped".  Events that are not durations (a retry, a
//! resurrection, a journal-dedup answer) travel as string `notes` on
//! the hop that observed them.
//!
//! The coordinator pushes one record per retired request into a
//! [`TraceRing`]; the front door keeps its own ring of *joined*
//! cross-hop records.  Rings are fixed-capacity `VecDeque`s — the
//! observability layer never holds unbounded per-request memory — and
//! render as JSON lines for `GET /traces` and single objects for
//! `GET /trace/<id>`.

use std::collections::VecDeque;
use std::sync::Mutex;

/// One named stage inside a hop: `start_us` is the offset from the
/// *hop's* start (not from any global clock), `dur_us` its duration.
/// Engine stage spans (short-conv, modal sweep, the GEMV projections)
/// interleave per token, so they carry `start_us == 0` and their
/// `dur_us` is the per-request aggregate.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Span {
    pub name: String,
    pub start_us: u64,
    pub dur_us: u64,
}

impl Span {
    pub fn new(name: &str, start_us: u64, dur_us: u64) -> Self {
        Span { name: name.to_string(), start_us, dur_us }
    }

    fn to_json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"start_us\":{},\"dur_us\":{}}}",
            escape(&self.name),
            self.start_us,
            self.dur_us
        )
    }
}

/// One layer's view of a request: where its `total_us` went, as spans
/// offset from the hop's own start, plus annotations for events that
/// are not durations ("retry:2", "resurrected", "refused:overloaded").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HopReport {
    /// Which layer reported: "front", "router", "shard", "coordinator",
    /// "engine".
    pub hop: String,
    /// The hop's own start-to-finish time for this request.
    pub total_us: u64,
    pub spans: Vec<Span>,
    pub notes: Vec<String>,
}

impl HopReport {
    pub fn new(hop: &str, total_us: u64) -> Self {
        HopReport { hop: hop.to_string(), total_us, spans: Vec::new(), notes: Vec::new() }
    }

    /// Append a named span; returns `self` for chaining.
    pub fn span(mut self, name: &str, start_us: u64, dur_us: u64) -> Self {
        self.spans.push(Span::new(name, start_us, dur_us));
        self
    }

    pub fn note(mut self, note: &str) -> Self {
        self.notes.push(note.to_string());
        self
    }

    /// The named span, if the stage happened on this hop at all.
    pub fn span_named(&self, name: &str) -> Option<&Span> {
        self.spans.iter().find(|s| s.name == name)
    }

    fn to_json(&self) -> String {
        let spans: Vec<String> = self.spans.iter().map(|s| s.to_json()).collect();
        let notes: Vec<String> =
            self.notes.iter().map(|n| format!("\"{}\"", escape(n))).collect();
        format!(
            "{{\"hop\":\"{}\",\"total_us\":{},\"spans\":[{}],\"notes\":[{}]}}",
            escape(&self.hop),
            self.total_us,
            spans.join(","),
            notes.join(",")
        )
    }
}

/// One request's joined timeline: the trace id minted at the front
/// door, every hop's report in traversal order (front first, engine
/// last), and the envelope facts every consumer wants without walking
/// the tree.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceRecord {
    /// The wire-propagated 64-bit trace id (never 0 for traced work).
    pub id: u64,
    pub session: Option<u64>,
    /// False when the request ended in an error instead of a reply.
    pub ok: bool,
    /// Tokens generated.
    pub tokens: u32,
    /// End-to-end latency as observed by the *outermost* recorded hop.
    pub e2e_us: u64,
    pub hops: Vec<HopReport>,
}

impl TraceRecord {
    /// The named hop's report, if that layer contributed one.
    pub fn hop(&self, name: &str) -> Option<&HopReport> {
        self.hops.iter().find(|h| h.hop == name)
    }

    /// True if any hop carries the note (exact match or `prefix:`-style
    /// prefix match, e.g. `has_note("retry")` matches "retry:2").
    pub fn has_note(&self, note: &str) -> bool {
        self.hops.iter().any(|h| {
            h.notes.iter().any(|n| {
                n == note || (n.starts_with(note) && n.as_bytes().get(note.len()) == Some(&b':'))
            })
        })
    }

    /// One JSON object, no trailing newline.  Field order is fixed so
    /// the output is line-diffable; skipped stages are *absent* from
    /// `spans`, never rendered as zeros.
    pub fn to_json(&self) -> String {
        let session = match self.session {
            Some(s) => s.to_string(),
            None => "null".to_string(),
        };
        let hops: Vec<String> = self.hops.iter().map(|h| h.to_json()).collect();
        format!(
            "{{\"id\":{},\"session\":{},\"ok\":{},\"tokens\":{},\"e2e_us\":{},\"hops\":[{}]}}",
            self.id,
            session,
            self.ok,
            self.tokens,
            self.e2e_us,
            hops.join(",")
        )
    }
}

/// Minimal JSON string escape for hop/span/note text (quotes,
/// backslashes, control bytes) — trace text is internal, but an error
/// message quoted into a note must not break the rendering.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Capacity of a ring unless the caller picks one: enough recent
/// context to debug a latency spike, small enough to never matter.
pub const DEFAULT_TRACE_CAP: usize = 256;

/// Bounded ring of recent trace records, oldest evicted first.
pub struct TraceRing {
    inner: Mutex<VecDeque<TraceRecord>>,
    cap: usize,
}

impl Default for TraceRing {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_TRACE_CAP)
    }
}

impl TraceRing {
    pub fn with_capacity(cap: usize) -> Self {
        TraceRing { inner: Mutex::new(VecDeque::with_capacity(cap.max(1))), cap: cap.max(1) }
    }

    pub fn push(&self, t: TraceRecord) {
        let mut r = self.inner.lock().unwrap();
        if r.len() == self.cap {
            r.pop_front();
        }
        r.push_back(t);
    }

    /// Most recent traces, oldest first.
    pub fn recent(&self) -> Vec<TraceRecord> {
        self.inner.lock().unwrap().iter().cloned().collect()
    }

    /// The most recent record for the trace id, if it is still in the
    /// ring — backs `GET /trace/<id>`.
    pub fn find(&self, id: u64) -> Option<TraceRecord> {
        self.inner.lock().unwrap().iter().rev().find(|t| t.id == id).cloned()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// JSON-lines rendering for `GET /traces`: one object per line,
    /// oldest first, trailing newline when non-empty.  `session`
    /// filters to one session's turns (`GET /traces?session=<id>`).
    pub fn to_json_lines(&self, session: Option<u64>) -> String {
        let mut out = String::new();
        for t in self.inner.lock().unwrap().iter() {
            if session.is_some() && t.session != session {
                continue;
            }
            out.push_str(&t.to_json());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_fifo() {
        let ring = TraceRing::with_capacity(3);
        for i in 0..10u64 {
            ring.push(TraceRecord { id: i, ok: true, ..TraceRecord::default() });
        }
        let recent = ring.recent();
        assert_eq!(recent.len(), 3);
        assert_eq!(
            recent.iter().map(|t| t.id).collect::<Vec<_>>(),
            vec![7, 8, 9],
            "oldest evicted first"
        );
        assert_eq!(ring.find(8).unwrap().id, 8);
        assert!(ring.find(2).is_none(), "evicted ids are gone");
    }

    /// Pins the JSON shape: fixed key order, hops/spans/notes nested,
    /// `session:null` for one-shots.
    #[test]
    fn json_lines_are_stable() {
        let ring = TraceRing::with_capacity(8);
        let front = HopReport::new("front", 900)
            .span("queue", 0, 10)
            .span("relay", 10, 890);
        let coord = HopReport::new("coordinator", 700)
            .span("queue", 0, 5)
            .span("prefill", 5, 195)
            .span("decode", 200, 500)
            .note("retry:1");
        ring.push(TraceRecord {
            id: 1,
            session: Some(42),
            ok: true,
            tokens: 8,
            e2e_us: 900,
            hops: vec![front, coord],
        });
        ring.push(TraceRecord { id: 2, ok: false, ..TraceRecord::default() });
        assert_eq!(
            ring.to_json_lines(None),
            "{\"id\":1,\"session\":42,\"ok\":true,\"tokens\":8,\"e2e_us\":900,\"hops\":[\
             {\"hop\":\"front\",\"total_us\":900,\"spans\":[\
             {\"name\":\"queue\",\"start_us\":0,\"dur_us\":10},\
             {\"name\":\"relay\",\"start_us\":10,\"dur_us\":890}],\"notes\":[]},\
             {\"hop\":\"coordinator\",\"total_us\":700,\"spans\":[\
             {\"name\":\"queue\",\"start_us\":0,\"dur_us\":5},\
             {\"name\":\"prefill\",\"start_us\":5,\"dur_us\":195},\
             {\"name\":\"decode\",\"start_us\":200,\"dur_us\":500}],\"notes\":[\"retry:1\"]}]}\n\
             {\"id\":2,\"session\":null,\"ok\":false,\"tokens\":0,\"e2e_us\":0,\"hops\":[]}\n"
        );
    }

    /// A skipped stage is *absent*, not zero: a state-resume turn's
    /// coordinator hop simply has no "prefill" span, which is
    /// distinguishable from a prefill that measured 0µs.
    #[test]
    fn skipped_stages_are_absent_not_zero() {
        let resumed = HopReport::new("coordinator", 100)
            .span("queue", 0, 2)
            .span("decode", 2, 98);
        assert!(resumed.span_named("prefill").is_none(), "skipped stage is absent");
        let instant = HopReport::new("coordinator", 100)
            .span("queue", 0, 2)
            .span("prefill", 2, 0)
            .span("decode", 2, 98);
        assert_eq!(instant.span_named("prefill").unwrap().dur_us, 0);
        // the two shapes render differently — the old flat-record
        // ambiguity ("prefill_us:0" meaning either) is gone
        let r = |h: HopReport| TraceRecord { id: 9, hops: vec![h], ..Default::default() }.to_json();
        let resumed_json = r(resumed);
        let instant_json = r(instant);
        assert!(!resumed_json.contains("\"name\":\"prefill\""), "{resumed_json}");
        assert!(instant_json.contains("{\"name\":\"prefill\",\"start_us\":2,\"dur_us\":0}"));
    }

    #[test]
    fn session_filter_and_note_lookup() {
        let ring = TraceRing::with_capacity(8);
        ring.push(TraceRecord { id: 1, session: Some(5), ..Default::default() });
        ring.push(TraceRecord { id: 2, session: Some(6), ..Default::default() });
        ring.push(TraceRecord { id: 3, session: Some(5), ..Default::default() });
        let only5 = ring.to_json_lines(Some(5));
        assert!(only5.contains("\"id\":1") && only5.contains("\"id\":3"));
        assert!(!only5.contains("\"id\":2"));
        let t = TraceRecord {
            id: 4,
            hops: vec![HopReport::new("router", 10).note("retry:2").note("resurrected")],
            ..Default::default()
        };
        assert!(t.has_note("retry"));
        assert!(t.has_note("retry:2"));
        assert!(t.has_note("resurrected"));
        assert!(!t.has_note("resur"), "prefix match requires a ':' boundary");
    }

    #[test]
    fn notes_with_quotes_escape_cleanly() {
        let t = TraceRecord {
            id: 7,
            hops: vec![HopReport::new("router", 1).note("refused:\"why\"\n")],
            ..Default::default()
        };
        assert!(t.to_json().contains("refused:\\\"why\\\"\\n"));
    }

    #[test]
    fn empty_ring_renders_empty() {
        let ring = TraceRing::default();
        assert!(ring.is_empty());
        assert_eq!(ring.to_json_lines(None), "");
    }
}
