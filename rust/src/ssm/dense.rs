//! Unstructured dense state-space model (paper eq. 2.2): the generic
//! realization with O(d^2) step cost that Lemma A.8 canonizes into the O(d)
//! companion form.

use super::transfer::TransferFunction;
use crate::linalg::Mat;

/// Dense SISO SSM: x' = A x + B u, y = C x + h0 u.
#[derive(Clone, Debug)]
pub struct DenseSsm {
    pub a: Mat,
    pub b: Vec<f64>,
    pub c: Vec<f64>,
    pub h0: f64,
}

impl DenseSsm {
    pub fn new(a: Mat, b: Vec<f64>, c: Vec<f64>, h0: f64) -> Self {
        assert_eq!(a.rows, a.cols);
        assert_eq!(a.rows, b.len());
        assert_eq!(a.rows, c.len());
        DenseSsm { a, b, c, h0 }
    }

    pub fn order(&self) -> usize {
        self.b.len()
    }

    /// One O(d^2) step; returns y_t computed from the pre-update state.
    pub fn step(&self, state: &mut Vec<f64>, u: f64) -> f64 {
        let y = self.c.iter().zip(state.iter()).map(|(c, x)| c * x).sum::<f64>()
            + self.h0 * u;
        let ax = self.a.matvec(state);
        for (i, x) in state.iter_mut().enumerate() {
            *x = ax[i] + self.b[i] * u;
        }
        y
    }

    pub fn filter(&self, u: &[f64]) -> Vec<f64> {
        let mut st = vec![0.0; self.order()];
        u.iter().map(|&x| self.step(&mut st, x)).collect()
    }

    /// Impulse-response taps [h_1 .. h_len] = C A^{t-1} B.
    pub fn impulse_response(&self, len: usize) -> Vec<f64> {
        let mut v = self.b.clone();
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.c.iter().zip(&v).map(|(c, x)| c * x).sum());
            v = self.a.matvec(&v);
        }
        out
    }

    /// Similarity transform x̂ = K x (Lemma A.3 invariance):
    /// Â = K A K^{-1}, B̂ = K B, Ĉ = C K^{-1}.
    pub fn transformed(&self, k: &Mat, k_inv: &Mat) -> DenseSsm {
        DenseSsm {
            a: k.matmul(&self.a).matmul(k_inv),
            b: k.matvec(&self.b),
            c: k_inv.transpose().matvec(&self.c),
            h0: self.h0,
        }
    }

    /// Canonize (Theorem A.8): dense → transfer function → companion; the
    /// result has an O(d) recurrence with identical input-output behaviour.
    pub fn canonize(&self) -> super::companion::CompanionSsm {
        TransferFunction::from_dense(&self.a, &self.b, &self.c, self.h0).to_companion()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::lu::solve_real;
    use crate::util::prop::{assert_close, check};
    use crate::util::Prng;

    fn random_stable_dense(rng: &mut Prng, d: usize) -> DenseSsm {
        // random A scaled to spectral radius ~0.8
        let mut a = Mat::from_fn(d, d, |_, _| rng.normal());
        let sn = a.spectral_norm().max(1e-6);
        a = a.scale(0.8 / sn);
        DenseSsm::new(a, rng.normal_vec(d), rng.normal_vec(d), rng.normal())
    }

    #[test]
    fn impulse_response_matches_stepping() {
        check("dense impulse == step", 12, |rng| {
            let d = 1 + rng.below(6);
            let sys = random_stable_dense(rng, d);
            let mut u = vec![0.0; 16];
            u[0] = 1.0;
            let y = sys.filter(&u);
            let h = sys.impulse_response(15);
            if (y[0] - sys.h0).abs() > 1e-10 {
                return Err("h0".into());
            }
            assert_close(&y[1..], &h, 1e-9, 1e-9)
        });
    }

    #[test]
    fn transfer_function_is_similarity_invariant() {
        // Lemma A.3: transformed system has the same impulse response
        check("similarity invariance", 10, |rng| {
            let d = 2 + rng.below(4);
            let sys = random_stable_dense(rng, d);
            // random well-conditioned K = I + small noise
            let k = Mat::from_fn(d, d, |i, j| {
                (if i == j { 1.0 } else { 0.0 }) + 0.2 * rng.normal()
            });
            // invert K column by column
            let mut k_inv = Mat::zeros(d, d);
            for col in 0..d {
                let mut e = vec![0.0; d];
                e[col] = 1.0;
                let x = match solve_real(&k, &e) {
                    Some(x) => x,
                    None => return Ok(()),
                };
                for r in 0..d {
                    k_inv[(r, col)] = x[r];
                }
            }
            let sys2 = sys.transformed(&k, &k_inv);
            assert_close(
                &sys2.impulse_response(20),
                &sys.impulse_response(20),
                1e-6,
                1e-6,
            )
        });
    }

    #[test]
    fn canonization_preserves_behaviour_and_speeds_step() {
        check("dense canonize == dense behaviour", 10, |rng| {
            let d = 2 + rng.below(4);
            let sys = random_stable_dense(rng, d);
            let comp = sys.canonize();
            let u = rng.normal_vec(24);
            assert_close(&comp.filter(&u), &sys.filter(&u), 2e-5, 2e-5)
        });
    }
}
