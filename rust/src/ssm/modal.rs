//! Modal (diagonal) state-space model — the distillation target form.
//!
//! h_hat_t = Re( sum_n R_n lambda_n^{t-1} ) for t > 0, plus the h0
//! passthrough (paper eq. 3.2, Prop. 3.3).  B is fixed to ones; the
//! residues live in C (paper App. B.1 — parametrizing both B and C is
//! redundant).

use crate::dsp::C64;

/// Diagonal SSM with complex poles and residues.
#[derive(Clone, Debug)]
pub struct ModalSsm {
    /// Poles lambda_n (eigenvalues of the diagonal A).
    pub poles: Vec<C64>,
    /// Residues R_n (entries of C, with B = ones).
    pub residues: Vec<C64>,
    /// Passthrough tap h_0.
    pub h0: f64,
}

/// Recurrent state for a [`ModalSsm`].
#[derive(Clone, Debug)]
pub struct ModalState(pub Vec<C64>);

impl ModalSsm {
    pub fn new(poles: Vec<C64>, residues: Vec<C64>, h0: f64) -> Self {
        assert_eq!(poles.len(), residues.len());
        ModalSsm { poles, residues, h0 }
    }

    /// State dimension d.
    pub fn order(&self) -> usize {
        self.poles.len()
    }

    /// Spectral radius rho(A) = max |lambda|.
    pub fn spectral_radius(&self) -> f64 {
        self.poles.iter().map(|l| l.abs()).fold(0.0, f64::max)
    }

    /// Stable iff every pole lies strictly inside the unit circle.
    pub fn is_stable(&self) -> bool {
        self.spectral_radius() < 1.0
    }

    /// Impulse-response taps [h_1 .. h_len] (tau-indexed: out[tau] = h_{tau+1}
    /// = Re sum_n R_n lambda_n^tau). O(d len) via incremental powers.
    pub fn impulse_response(&self, len: usize) -> Vec<f64> {
        let d = self.order();
        let mut pow: Vec<C64> = vec![C64::ONE; d];
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            let mut acc = 0.0;
            for n in 0..d {
                acc += (self.residues[n] * pow[n]).re;
                pow[n] *= self.poles[n];
            }
            out.push(acc);
        }
        out
    }

    /// Fresh zero state.
    pub fn zero_state(&self) -> ModalState {
        ModalState(vec![C64::ZERO; self.order()])
    }

    /// One recurrent step (Prop. 3.3): y_t = Re<R, x_t> + h0 u_t, then
    /// x_{t+1} = diag(lambda) x_t + 1 u_t.  O(d) time and memory.
    pub fn step(&self, state: &mut ModalState, u: f64) -> f64 {
        let mut y = self.h0 * u;
        for n in 0..self.order() {
            y += (self.residues[n] * state.0[n]).re;
            state.0[n] = self.poles[n] * state.0[n] + C64::real(u);
        }
        y
    }

    /// Run the recurrence over an input sequence, producing all outputs.
    pub fn filter(&self, u: &[f64]) -> Vec<f64> {
        let mut st = self.zero_state();
        u.iter().map(|&x| self.step(&mut st, x)).collect()
    }

    /// Prefill by plain recurrence: state after consuming all of `u`
    /// (O(dT) time, O(d) memory — the Lemma 2.2 baseline path).
    pub fn prefill_recurrent(&self, u: &[f64]) -> ModalState {
        let mut st = self.zero_state();
        for &x in u {
            self.step(&mut st, x);
        }
        st
    }

    /// Truncation correction (App. A.4): the filter trained/used at length
    /// L behaves like the infinite one with residues R̄ = R (1 - lambda^L).
    pub fn truncation_corrected(&self, len: usize) -> ModalSsm {
        let residues = self
            .residues
            .iter()
            .zip(&self.poles)
            .map(|(r, l)| *r * (C64::ONE - l.powi(len as u64)))
            .collect();
        ModalSsm { poles: self.poles.clone(), residues, h0: self.h0 }
    }

    /// Invert the truncation correction: R = R̄ (1 - lambda^L)^{-1}
    /// (possibly ill-conditioned near the stability margin, as the paper
    /// warns).
    pub fn truncation_uncorrected(&self, len: usize) -> ModalSsm {
        let residues = self
            .residues
            .iter()
            .zip(&self.poles)
            .map(|(r, l)| *r / (C64::ONE - l.powi(len as u64)))
            .collect();
        ModalSsm { poles: self.poles.clone(), residues, h0: self.h0 }
    }

    /// Conjugate closure: the order-2d conjugate-closed system whose plain
    /// (complex) impulse response equals this system's *real-part* response
    /// Re sum R lambda^t — i.e. poles {lambda, conj lambda} with residues
    /// {R/2, conj R/2}.  Distilled systems are generally NOT conjugate-
    /// closed (the fit parametrizes poles freely and takes Re[.]), so any
    /// conversion to a real rational form must go through this closure.
    pub fn conjugate_closure(&self) -> ModalSsm {
        let mut poles = Vec::with_capacity(2 * self.order());
        let mut residues = Vec::with_capacity(2 * self.order());
        for (l, r) in self.poles.iter().zip(&self.residues) {
            poles.push(*l);
            residues.push(r.scale(0.5));
            poles.push(l.conj());
            residues.push(r.conj().scale(0.5));
        }
        ModalSsm { poles, residues, h0: self.h0 }
    }

    /// Build a conjugate-closed modal system from upper-half-plane
    /// (pole, residue) pairs; the impulse response is then exactly
    /// 2 sum Re(R lambda^tau)/... — here we simply include both halves.
    pub fn from_conjugate_pairs(pairs: &[(C64, C64)], h0: f64) -> ModalSsm {
        let mut poles = Vec::with_capacity(pairs.len() * 2);
        let mut residues = Vec::with_capacity(pairs.len() * 2);
        for &(l, r) in pairs {
            poles.push(l);
            residues.push(r);
            poles.push(l.conj());
            residues.push(r.conj());
        }
        ModalSsm { poles, residues, h0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::conv::causal_conv_direct;
    use crate::util::prop::{assert_close, check};
    use crate::util::Prng;

    fn random_stable(rng: &mut Prng, d: usize) -> ModalSsm {
        let poles: Vec<C64> = (0..d)
            .map(|_| C64::polar(rng.range(0.2, 0.95), rng.range(-3.0, 3.0)))
            .collect();
        let residues: Vec<C64> =
            (0..d).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        ModalSsm::new(poles, residues, rng.normal())
    }

    #[test]
    fn step_reproduces_impulse_response() {
        check("modal step impulse == closed form", 16, |rng| {
            let d = 1 + rng.below(8);
            let sys = random_stable(rng, d);
            let mut u = vec![0.0; 24];
            u[0] = 1.0;
            let y = sys.filter(&u);
            let h = sys.impulse_response(23);
            if (y[0] - sys.h0).abs() > 1e-10 {
                return Err(format!("h0: {} vs {}", y[0], sys.h0));
            }
            assert_close(&y[1..], &h, 1e-9, 1e-9)
        });
    }

    #[test]
    fn filter_equals_convolution() {
        check("modal filter == conv with impulse response", 12, |rng| {
            let d = 1 + rng.below(6);
            let sys = random_stable(rng, d);
            let t = 30;
            let u = rng.normal_vec(t);
            let got = sys.filter(&u);
            // full filter: [h0, h_1, h_2, ...]
            let mut taps = vec![sys.h0];
            taps.extend(sys.impulse_response(t - 1));
            let want = causal_conv_direct(&taps, &u);
            assert_close(&got, &want, 1e-8, 1e-8)
        });
    }

    #[test]
    fn conjugate_pairs_give_real_output() {
        check("conjugate-closed system has real response", 12, |rng| {
            let pairs: Vec<(C64, C64)> = (0..3)
                .map(|_| {
                    (
                        C64::polar(rng.range(0.3, 0.9), rng.range(0.1, 3.0)),
                        C64::new(rng.normal(), rng.normal()),
                    )
                })
                .collect();
            let sys = ModalSsm::from_conjugate_pairs(&pairs, 0.0);
            // impulse response must already be real by construction; check
            // the imaginary parts cancel by comparing against the doubled
            // real-part formula.
            let h = sys.impulse_response(16);
            let manual: Vec<f64> = (0..16)
                .map(|t| {
                    pairs
                        .iter()
                        .map(|(l, r)| 2.0 * (*r * l.powi(t as u64)).re)
                        .sum()
                })
                .collect();
            assert_close(&h, &manual, 1e-9, 1e-9)
        });
    }

    #[test]
    fn stability_checks() {
        let stable = ModalSsm::new(vec![C64::polar(0.9, 1.0)], vec![C64::ONE], 0.0);
        assert!(stable.is_stable());
        let unstable = ModalSsm::new(vec![C64::polar(1.1, 1.0)], vec![C64::ONE], 0.0);
        assert!(!unstable.is_stable());
        assert!((unstable.spectral_radius() - 1.1).abs() < 1e-12);
    }

    #[test]
    fn truncation_correction_roundtrip() {
        check("correction then inverse is identity", 12, |rng| {
            let sys = random_stable(rng, 4);
            let back = sys.truncation_corrected(32).truncation_uncorrected(32);
            for (a, b) in back.residues.iter().zip(&sys.residues) {
                if (*a - *b).abs() > 1e-9 * (1.0 + b.abs()) {
                    return Err("residue mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prefill_recurrent_matches_direct_sum() {
        check("prefill state == sum lambda^(T-1-j) u_j", 12, |rng| {
            let sys = random_stable(rng, 3);
            let t = 20;
            let u = rng.normal_vec(t);
            let st = sys.prefill_recurrent(&u);
            for (n, &l) in sys.poles.iter().enumerate() {
                let mut want = C64::ZERO;
                for (j, &x) in u.iter().enumerate() {
                    want += l.powi((t - 1 - j) as u64) * C64::real(x);
                }
                if (st.0[n] - want).abs() > 1e-8 * (1.0 + want.abs()) {
                    return Err(format!("mode {n}"));
                }
            }
            Ok(())
        });
    }
}
