//! Companion canonical form (paper App. A.5) with the O(d) fast recurrence
//! of Lemma A.7: the state matrix is a lower shift plus a rank-one term, so
//! a step is one shift and two inner products — no matrix ever materialized.

use crate::dsp::C64;
use crate::linalg::Mat;

/// Companion-form SSM: x' = (L - e1 ⊗ alpha) x + e1 u, y = beta^T x + b0 u.
#[derive(Clone, Debug)]
pub struct CompanionSsm {
    /// Denominator coefficients [a1 .. ad].
    pub alpha: Vec<f64>,
    /// Output coefficients [beta1 .. betad] (already h0-corrected).
    pub beta: Vec<f64>,
    /// Passthrough b0 = h0.
    pub b0: f64,
}

/// Ring-buffer state for the companion recurrence (the shift is O(1) by
/// moving the head pointer instead of memmoving d elements).
#[derive(Clone, Debug)]
pub struct CompanionState {
    buf: Vec<f64>,
    head: usize, // index of x^1 (most recent)
}

impl CompanionState {
    /// Canonical-order view (x^1 .. x^d) of the ring buffer.
    pub fn snapshot(&self, d: usize) -> Vec<f64> {
        (0..d).map(|k| self.buf[(self.head + k) % d.max(1)]).collect()
    }
}

impl CompanionSsm {
    pub fn new(alpha: Vec<f64>, beta: Vec<f64>, b0: f64) -> Self {
        assert_eq!(alpha.len(), beta.len());
        CompanionSsm { alpha, beta, b0 }
    }

    pub fn order(&self) -> usize {
        self.alpha.len()
    }

    pub fn zero_state(&self) -> CompanionState {
        CompanionState { buf: vec![0.0; self.order().max(1)], head: 0 }
    }

    /// One recurrent step (Listing 2): y = <beta, x> + b0 u;
    /// x1' = u - <alpha, x>; shift.  O(d).
    pub fn step(&self, st: &mut CompanionState, u: f64) -> f64 {
        let d = self.order();
        if d == 0 {
            return self.b0 * u;
        }
        let mut y = self.b0 * u;
        let mut lr = u;
        // x^k = buf[(head + k - 1) % d]
        for k in 0..d {
            let x = st.buf[(st.head + k) % d];
            y += self.beta[k] * x;
            lr -= self.alpha[k] * x;
        }
        // shift: new head holds x1' = lr
        st.head = (st.head + d - 1) % d;
        st.buf[st.head] = lr;
        y
    }

    pub fn filter(&self, u: &[f64]) -> Vec<f64> {
        let mut st = self.zero_state();
        u.iter().map(|&x| self.step(&mut st, x)).collect()
    }

    /// Impulse response taps [h_1 .. h_len] (h_0 = b0 excluded).
    pub fn impulse_response(&self, len: usize) -> Vec<f64> {
        let mut u = vec![0.0; len + 1];
        u[0] = 1.0;
        self.filter(&u)[1..].to_vec()
    }

    /// Prop. 3.2 FFT prefill: the companion state after a length-T prompt is
    /// x_T = (v_{T-1}, ..., v_{T-d}) where v = g * u and G = 1/den.
    /// Computed here exactly in O(dT) via the v-recurrence; callers that
    /// want the Õ(T) variant convolve with
    /// [`super::transfer::TransferFunction::prefill_filter`] via FFT.
    pub fn prefill_direct(&self, u: &[f64]) -> CompanionState {
        let d = self.order();
        let t = u.len();
        let mut v = vec![0.0; t];
        for i in 0..t {
            let mut acc = u[i];
            for j in 1..=d.min(i) {
                acc -= self.alpha[j - 1] * v[i - j];
            }
            v[i] = acc;
        }
        let mut st = self.zero_state();
        // x^k = v_{T-k}
        for k in 0..d {
            let idx = t as isize - 1 - k as isize;
            st.buf[k] = if idx >= 0 { v[idx as usize] } else { 0.0 };
        }
        st.head = 0;
        st
    }

    /// Dense (A, B, C, h0) realization (paper eq. A.8) — used by tests and
    /// by conversions that need an explicit matrix.
    pub fn to_dense(&self) -> (Mat, Vec<f64>, Vec<f64>, f64) {
        let d = self.order();
        let mut a = Mat::zeros(d, d);
        for j in 0..d {
            a[(0, j)] = -self.alpha[j];
        }
        for i in 1..d {
            a[(i, i - 1)] = 1.0;
        }
        let mut b = vec![0.0; d];
        if d > 0 {
            b[0] = 1.0;
        }
        (a, b, self.beta.clone(), self.b0)
    }

    /// Poles = eigenvalues of the companion matrix = denominator roots.
    pub fn poles(&self) -> Vec<C64> {
        let d = self.order();
        let mut coeffs: Vec<C64> = Vec::with_capacity(d + 1);
        for k in (1..=d).rev() {
            coeffs.push(C64::real(self.alpha[k - 1]));
        }
        coeffs.push(C64::ONE);
        crate::dsp::poly::poly_roots(&coeffs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssm::modal::ModalSsm;
    use crate::ssm::transfer::TransferFunction;
    use crate::util::prop::{assert_close, check};
    use crate::util::Prng;

    fn random_modal(rng: &mut Prng, pairs: usize) -> ModalSsm {
        let ps: Vec<(crate::dsp::C64, crate::dsp::C64)> = (0..pairs)
            .map(|_| {
                (
                    crate::dsp::C64::polar(rng.range(0.3, 0.9), rng.range(0.2, 2.9)),
                    crate::dsp::C64::new(rng.normal(), rng.normal()),
                )
            })
            .collect();
        ModalSsm::from_conjugate_pairs(&ps, rng.normal())
    }

    #[test]
    fn companion_matches_transfer_function() {
        check("companion step == tf recurrence", 16, |rng| {
            let pairs = 1 + rng.below(3);
            let sys = random_modal(rng, pairs);
            let tf = TransferFunction::from_modal(&sys);
            let comp = tf.to_companion();
            let u = rng.normal_vec(30);
            let got = comp.filter(&u);
            // reference: convolve with the exact impulse response
            let taps = tf.impulse_response(30);
            let want = crate::dsp::conv::causal_conv_direct(&taps, &u);
            assert_close(&got, &want, 1e-6, 1e-6)
        });
    }

    #[test]
    fn canonization_theorem_a8() {
        // dense -> tf -> companion preserves the impulse response
        check("canonization preserves IO behaviour", 10, |rng| {
            let sys = random_modal(rng, 2);
            let tf = TransferFunction::from_modal(&sys);
            let comp = tf.to_companion();
            let (a, b, c, h0) = comp.to_dense();
            let tf2 = TransferFunction::from_dense(&a, &b, &c, h0);
            assert_close(
                &tf2.impulse_response(24),
                &tf.impulse_response(24),
                1e-5,
                1e-5,
            )
        });
    }

    #[test]
    fn prefill_direct_matches_stepping() {
        check("prop 3.2 prefill == stepped state", 12, |rng| {
            let sys = random_modal(rng, 2);
            let comp = TransferFunction::from_modal(&sys).to_companion();
            let u = rng.normal_vec(25);
            // state by stepping
            let mut st = comp.zero_state();
            for &x in &u {
                comp.step(&mut st, x);
            }
            let fast = comp.prefill_direct(&u);
            let d = comp.order();
            for k in 0..d {
                let a = st.buf[(st.head + k) % d];
                let b = fast.buf[(fast.head + k) % d];
                if (a - b).abs() > 1e-8 * (1.0 + b.abs()) {
                    return Err(format!("x^{k}: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn poles_match_modal_poles() {
        let mut rng = Prng::new(5);
        let sys = random_modal(&mut rng, 2);
        let comp = TransferFunction::from_modal(&sys).to_companion();
        let got = comp.poles();
        for l in &sys.poles {
            let best = got.iter().map(|g| (*g - *l).abs()).fold(f64::MAX, f64::min);
            assert!(best < 1e-6, "pole {l:?} unmatched ({best:.2e})");
        }
    }

    #[test]
    fn zero_order_passthrough() {
        let c = CompanionSsm::new(vec![], vec![], 2.5);
        let y = c.filter(&[1.0, -2.0]);
        assert_eq!(y, vec![2.5, -5.0]);
    }
}
