//! Shift SSM: a truncated length-L filter viewed as an L-dimensional
//! state-space model whose state is the last L inputs (paper App. A.7).
//! This is the "conv cache" baseline — O(L) per step, O(L) memory — that
//! H3 uses for one of its filters and that LaughingHyena replaces with a
//! d ≪ L modal recurrence.

/// Truncated-filter SSM. `taps` = [h_0, h_1, ..., h_{L-1}] (h_0 included).
#[derive(Clone, Debug)]
pub struct ShiftSsm {
    pub taps: Vec<f64>,
}

/// Rolling input window (ring buffer), x_t = (u_{t-1}, ..., u_{t-L+1}).
#[derive(Clone, Debug)]
pub struct ShiftState {
    buf: Vec<f64>,
    head: usize,
}

impl ShiftSsm {
    pub fn new(taps: Vec<f64>) -> Self {
        assert!(!taps.is_empty());
        ShiftSsm { taps }
    }

    /// State dimension = L - 1 (the h0 tap needs no memory).
    pub fn order(&self) -> usize {
        self.taps.len() - 1
    }

    pub fn zero_state(&self) -> ShiftState {
        ShiftState { buf: vec![0.0; self.order().max(1)], head: 0 }
    }

    /// One step (eq. A.12): y = <h_1.., x> + h_0 u, then push u.
    pub fn step(&self, st: &mut ShiftState, u: f64) -> f64 {
        let d = self.order();
        let mut y = self.taps[0] * u;
        for k in 0..d {
            y += self.taps[k + 1] * st.buf[(st.head + k) % d.max(1)];
        }
        if d > 0 {
            st.head = (st.head + d - 1) % d;
            st.buf[st.head] = u;
        }
        y
    }

    pub fn filter(&self, u: &[f64]) -> Vec<f64> {
        let mut st = self.zero_state();
        u.iter().map(|&x| self.step(&mut st, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::conv::causal_conv_direct;
    use crate::util::prop::{assert_close, check};

    #[test]
    fn equals_direct_convolution() {
        check("shift ssm == convolution", 16, |rng| {
            let l = 1 + rng.below(12);
            let taps = rng.normal_vec(l);
            let u = rng.normal_vec(20);
            let sys = ShiftSsm::new(taps.clone());
            assert_close(&sys.filter(&u), &causal_conv_direct(&taps, &u), 1e-10, 1e-10)
        });
    }

    #[test]
    fn single_tap_is_gain() {
        let sys = ShiftSsm::new(vec![3.0]);
        assert_eq!(sys.filter(&[1.0, 2.0]), vec![3.0, 6.0]);
    }

    #[test]
    fn order_is_len_minus_one() {
        assert_eq!(ShiftSsm::new(vec![1.0, 2.0, 3.0]).order(), 2);
    }
}
