//! Rational transfer functions H(z) in negative powers of z (paper eq. 3.1):
//!
//! ```text
//! H(z) = b0 + (b1 z^-1 + ... + bd z^-d) / (1 + a1 z^-1 + ... + ad z^-d)
//! ```
//!
//! stored *simply proper*: numerator `b` has d+1 entries (b0 = h0 included),
//! denominator `a` has d+1 entries with a[0] == 1.  The transfer function is
//! the invariant of the system (Lemma A.3); conversions in this module:
//! modal → tf (partial-fraction recombination), tf → companion (App. A.5,
//! including the h0 long division), dense ss → tf via `poly(eig(.))`
//! (App. A.6 / Listing 1), Õ(L) frequency/impulse evaluation (Lemma A.6),
//! and the Prop-3.2 prefill filter g = Z^{-1}[1/den].

use super::companion::CompanionSsm;
use super::modal::ModalSsm;
use crate::dsp::fft::{dft, idft};
use crate::dsp::poly::poly_from_roots;
use crate::dsp::C64;
use crate::linalg::eig::eig_real;
use crate::linalg::Mat;

/// Simply-proper rational transfer function in z^{-1}.
#[derive(Clone, Debug)]
pub struct TransferFunction {
    /// Numerator [b0, b1, .., bd].
    pub b: Vec<f64>,
    /// Denominator [1, a1, .., ad].
    pub a: Vec<f64>,
}

impl TransferFunction {
    pub fn new(b: Vec<f64>, a: Vec<f64>) -> Self {
        assert!(!a.is_empty() && (a[0] - 1.0).abs() < 1e-9, "denominator must be monic in z^0");
        TransferFunction { b, a }
    }

    /// Order d (denominator degree).
    pub fn order(&self) -> usize {
        self.a.len() - 1
    }

    /// Evaluate H at a point z (Horner in z^{-1}).
    pub fn eval(&self, z: C64) -> C64 {
        let zi = z.recip();
        let horner = |c: &[f64]| {
            let mut acc = C64::ZERO;
            for &x in c.iter().rev() {
                acc = acc * zi + C64::real(x);
            }
            acc
        };
        horner(&self.b) / horner(&self.a)
    }

    /// Frequency response on the L roots of unity in Õ(L) (Lemma A.6):
    /// FFT(zero-padded b) / FFT(zero-padded a).
    /// Convention: bin k holds H(e^{+2 pi i k / L}) — the DFT kernel
    /// e^{-2 pi i k t / L} plays the role of z^{-t}.
    pub fn freq_response(&self, l: usize) -> Vec<C64> {
        assert!(l > self.order(), "need L > d for the FFT evaluation");
        let pad = |c: &[f64]| {
            let mut buf = vec![C64::ZERO; l];
            for (i, &x) in c.iter().enumerate() {
                buf[i] = C64::real(x);
            }
            dft(&buf)
        };
        let num = pad(&self.b);
        let den = pad(&self.a);
        num.into_iter().zip(den).map(|(n, d)| n / d).collect()
    }

    /// Impulse response [h_0, h_1, ..., h_{len-1}] via the exact difference
    /// equation h_t = b_t - sum_j a_j h_{t-j} (O(d len); alias-free, unlike
    /// the inverse-FFT route for slowly decaying filters).
    pub fn impulse_response(&self, len: usize) -> Vec<f64> {
        let d = self.order();
        let mut h = vec![0.0; len];
        for t in 0..len {
            let mut acc = self.b.get(t).copied().unwrap_or(0.0);
            for j in 1..=d.min(t) {
                acc -= self.a[j] * h[t - j];
            }
            h[t] = acc;
        }
        h
    }

    /// Impulse response via inverse FFT of the frequency response — the
    /// Õ(L) path of Lemma A.6.  Subject to circular aliasing ~ rho^L; pad
    /// with `oversample` >= 1 to push the alias floor down.
    pub fn impulse_response_fft(&self, len: usize, oversample: usize) -> Vec<f64> {
        let l = (len * oversample.max(1)).next_power_of_two();
        let spec = self.freq_response(l);
        idft(&spec).into_iter().take(len).map(|z| z.re).collect()
    }

    /// Prop. 3.2 prefill filter g = Z^{-1}[1 / den(H)]: g_t satisfies
    /// g_t = delta_t - sum_j a_j g_{t-j}.
    pub fn prefill_filter(&self, len: usize) -> Vec<f64> {
        let d = self.order();
        let mut g = vec![0.0; len];
        for t in 0..len {
            let mut acc = if t == 0 { 1.0 } else { 0.0 };
            for j in 1..=d.min(t) {
                acc -= self.a[j] * g[t - j];
            }
            g[t] = acc;
        }
        g
    }

    /// Partial-fraction recombination: modal form → rational form.
    /// H(z) = h0 + sum_n R_n/(z - lambda_n); the poles MUST be
    /// conjugate-closed for the coefficients to come out real — for
    /// distilled systems (free poles + Re[.] output) call
    /// [`ModalSsm::conjugate_closure`] first, or use
    /// [`TransferFunction::from_modal_real`] which does so automatically.
    pub fn from_modal(sys: &ModalSsm) -> Self {
        let d = sys.order();
        let den_pos = poly_from_roots(&sys.poles); // z-power coeffs, monic, len d+1
        // num(z) = sum_n R_n prod_{m != n} (z - lambda_m): degree d-1
        let mut num_pos = vec![C64::ZERO; d.max(1)];
        for n in 0..d {
            let others: Vec<C64> = sys
                .poles
                .iter()
                .enumerate()
                .filter(|(m, _)| *m != n)
                .map(|(_, &l)| l)
                .collect();
            let q = poly_from_roots(&others); // degree d-1
            for (k, &c) in q.iter().enumerate() {
                num_pos[k] += sys.residues[n] * c;
            }
        }
        // convert z-power rational of degree (d-1)/d to z^{-1} form:
        // b_j = num_pos[d-j] (j = 1..d), a_j = den_pos[d-j]
        let mut a = vec![0.0; d + 1];
        for j in 0..=d {
            a[j] = den_pos[d - j].re;
        }
        let mut b = vec![0.0; d + 1];
        b[0] = sys.h0;
        for j in 1..=d {
            let c = if d >= j { num_pos.get(d - j).copied().unwrap_or(C64::ZERO) } else { C64::ZERO };
            b[j] = c.re + sys.h0 * a[j]; // fold h0 into the simply-proper numerator
        }
        // normalize a[0] to exactly 1 (it is by construction)
        TransferFunction::new(b, a)
    }

    /// Real rational form of an arbitrary (not necessarily conjugate-
    /// closed) modal system whose output is Re[C x]: goes through the
    /// order-2d conjugate closure, so the result is exactly real.
    pub fn from_modal_real(sys: &ModalSsm) -> Self {
        Self::from_modal(&sys.conjugate_closure())
    }

    /// Dense state space → transfer function via eigenvalues
    /// (App. A.6, Listing 1): a = poly(eig(A)),
    /// b = poly(eig(A - B C)) + (h0 - 1) a.
    pub fn from_dense(a_mat: &Mat, b_vec: &[f64], c_vec: &[f64], h0: f64) -> Self {
        let d = a_mat.rows;
        let eig_a = eig_real(a_mat);
        let a_pos = real_coeffs(&poly_from_roots(&eig_a));
        let mut a_bc = a_mat.clone();
        for i in 0..d {
            for j in 0..d {
                a_bc[(i, j)] -= b_vec[i] * c_vec[j];
            }
        }
        let eig_abc = eig_real(&a_bc);
        let q_pos = real_coeffs(&poly_from_roots(&eig_abc));
        // numerator(z) = q(z) + (h0 - 1) p(z), both degree d (monic)
        let num_pos: Vec<f64> = q_pos
            .iter()
            .zip(&a_pos)
            .map(|(q, p)| q + (h0 - 1.0) * p)
            .collect();
        // z^{-1} form: coefficient of z^{d-j} becomes index j
        let a = (0..=d).map(|j| a_pos[d - j]).collect::<Vec<_>>();
        let b = (0..=d).map(|j| num_pos[d - j]).collect::<Vec<_>>();
        TransferFunction::new(b, a)
    }

    /// Companion canonical realization (App. A.5): isolates h0 = b0 by long
    /// division, beta_j = b_j - b0 a_j.
    pub fn to_companion(&self) -> CompanionSsm {
        let d = self.order();
        let b0 = self.b.first().copied().unwrap_or(0.0);
        let alpha: Vec<f64> = self.a[1..].to_vec();
        let beta: Vec<f64> = (1..=d)
            .map(|j| self.b.get(j).copied().unwrap_or(0.0) - b0 * self.a[j])
            .collect();
        CompanionSsm::new(alpha, beta, b0)
    }

    /// Poles (denominator roots in z).
    pub fn poles(&self) -> Vec<C64> {
        // den in z^{-1}: 1 + a1 z^-1 + ... + ad z^-d; roots of
        // z^d + a1 z^{d-1} + ... + ad (positive powers, reversed coeffs)
        let coeffs: Vec<C64> = self.a.iter().rev().map(|&x| C64::real(x)).collect();
        crate::dsp::poly::poly_roots(&coeffs)
    }

    /// Modal form via pole/residue expansion (Prop. 3.1): residues by
    /// R_n = num(lambda_n) / den'(lambda_n) evaluated in z-powers.
    pub fn to_modal(&self) -> ModalSsm {
        let d = self.order();
        let h0 = self.b.first().copied().unwrap_or(0.0);
        // strictly-proper numerator in z-powers: n(z) = sum_j beta_j z^{d-j}
        let beta: Vec<f64> = (1..=d)
            .map(|j| self.b.get(j).copied().unwrap_or(0.0) - h0 * self.a[j])
            .collect();
        let mut num_pos = vec![C64::ZERO; d]; // degree d-1
        for j in 1..=d {
            num_pos[d - j] = C64::real(beta[j - 1]);
        }
        let den_pos: Vec<C64> = self.a.iter().rev().map(|&x| C64::real(x)).collect();
        let dden = crate::dsp::poly::poly_deriv(&den_pos);
        let poles = self.poles();
        let residues: Vec<C64> = poles
            .iter()
            .map(|&l| {
                crate::dsp::poly::poly_eval(&num_pos, l)
                    / crate::dsp::poly::poly_eval(&dden, l)
            })
            .collect();
        ModalSsm::new(poles, residues, h0)
    }
}

fn real_coeffs(p: &[C64]) -> Vec<f64> {
    p.iter().map(|c| c.re).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};
    use crate::util::Prng;

    fn random_modal(rng: &mut Prng, pairs: usize) -> ModalSsm {
        let ps: Vec<(C64, C64)> = (0..pairs)
            .map(|_| {
                (
                    C64::polar(rng.range(0.3, 0.9), rng.range(0.2, 2.9)),
                    C64::new(rng.normal(), rng.normal()),
                )
            })
            .collect();
        ModalSsm::from_conjugate_pairs(&ps, rng.normal())
    }

    #[test]
    fn modal_to_tf_preserves_impulse_response() {
        check("modal -> tf impulse response", 16, |rng| {
            let pairs = 1 + rng.below(3);
            let sys = random_modal(rng, pairs);
            let tf = TransferFunction::from_modal(&sys);
            let want: Vec<f64> = {
                let mut v = vec![sys.h0];
                v.extend(sys.impulse_response(23));
                v
            };
            assert_close(&tf.impulse_response(24), &want, 1e-7, 1e-7)
        });
    }

    #[test]
    fn tf_roundtrip_through_modal() {
        check("tf -> modal -> tf", 12, |rng| {
            let pairs = 1 + rng.below(3);
            let sys = random_modal(rng, pairs);
            let tf = TransferFunction::from_modal(&sys);
            let back = TransferFunction::from_modal(&tf.to_modal());
            assert_close(
                &back.impulse_response(20),
                &tf.impulse_response(20),
                1e-6,
                1e-6,
            )
        });
    }

    #[test]
    fn freq_response_matches_pointwise_eval() {
        check("fft freq response == horner eval", 8, |rng| {
            let sys = random_modal(rng, 2);
            let tf = TransferFunction::from_modal(&sys);
            let l = 32;
            let fast = tf.freq_response(l);
            for k in 0..l {
                let z = C64::polar(1.0, 2.0 * std::f64::consts::PI * k as f64 / l as f64);
                let slow = tf.eval(z);
                if (fast[k] - slow).abs() > 1e-8 * (1.0 + slow.abs()) {
                    return Err(format!("bin {k}: {:?} vs {:?}", fast[k], slow));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn impulse_fft_matches_recurrence_for_stable_systems() {
        check("fft impulse == recurrence", 8, |rng| {
            let sys = random_modal(rng, 2);
            let tf = TransferFunction::from_modal(&sys);
            let exact = tf.impulse_response(32);
            let fft = tf.impulse_response_fft(32, 8);
            assert_close(&fft, &exact, 1e-4, 1e-4)
        });
    }

    #[test]
    fn prefill_filter_inverts_denominator() {
        check("a * g == delta", 12, |rng| {
            let sys = random_modal(rng, 2);
            let tf = TransferFunction::from_modal(&sys);
            let g = tf.prefill_filter(24);
            let conv = crate::dsp::conv::causal_conv_direct(&tf.a, &g);
            let mut delta = vec![0.0; 24];
            delta[0] = 1.0;
            assert_close(&conv, &delta, 1e-8, 1e-8)
        });
    }

    #[test]
    fn fir_transfer_function() {
        // pure FIR: denominator = [1]: impulse response == numerator taps
        let tf = TransferFunction::new(vec![0.5, -1.0, 2.0], vec![1.0]);
        assert_eq!(tf.impulse_response(5), vec![0.5, -1.0, 2.0, 0.0, 0.0]);
    }
}
