//! State-space realizations and conversions (paper §2, §3.4, App. A).
//!
//! Realization zoo:
//! * [`modal::ModalSsm`] — diagonal A with complex poles/residues, the form
//!   LaughingHyena distills into (eq. 3.2, Prop. 3.3): O(d) step.
//! * [`companion::CompanionSsm`] — companion canonical form (App. A.5):
//!   O(d) step via shift + two inner products (Lemma A.7).
//! * [`dense::DenseSsm`] — unstructured (A, B, C, h0): O(d^2) step; the
//!   thing you get from generic parametrizations, canonized via Lemma A.8.
//! * [`shift::ShiftSsm`] — truncated filter as an L-dim SSM (App. A.7):
//!   the "cache the last L inputs" baseline.
//! * [`transfer::TransferFunction`] — rational H(z) in z^{-1}, the
//!   invariant connecting all of the above (Lemma A.3).

pub mod companion;
pub mod dense;
pub mod modal;
pub mod shift;
pub mod transfer;

pub use companion::CompanionSsm;
pub use dense::DenseSsm;
pub use modal::ModalSsm;
pub use shift::ShiftSsm;
pub use transfer::TransferFunction;
