//! Artifact + checkpoint manifests — the text files aot.py emits alongside
//! every HLO artifact, describing the flattened PJRT argument order.

use anyhow::{bail, Context, Result};

/// Dtype tags used by aot.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype tag {other}"),
        }
    }
}

/// One flattened tensor slot (input or output).
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub index: usize,
    /// Dotted tree path, e.g. "0.layers.1.w_qkv".
    pub path: String,
    pub dtype: Dtype,
    /// Empty shape = scalar.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Parsed `<name>.manifest.txt` for an AOT artifact.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|d| d.parse::<usize>().context("bad shape dim"))
        .collect()
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> Result<ArtifactManifest> {
        let mut m = ArtifactManifest::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                bail!("bad manifest line: {line}");
            }
            let spec = TensorSpec {
                index: parts[1].parse()?,
                path: parts[2].to_string(),
                dtype: Dtype::parse(parts[3])?,
                shape: parse_shape(parts[4])?,
            };
            match parts[0] {
                "in" => m.inputs.push(spec),
                "out" => m.outputs.push(spec),
                other => bail!("bad manifest tag {other}"),
            }
        }
        // slots must arrive in index order (aot.py writes them that way)
        for (i, s) in m.inputs.iter().enumerate() {
            if s.index != i {
                bail!("input order broken at {i}");
            }
        }
        for (i, s) in m.outputs.iter().enumerate() {
            if s.index != i {
                bail!("output order broken at {i}");
            }
        }
        Ok(m)
    }

    pub fn load(path: &std::path::Path) -> Result<ArtifactManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Index of the first input whose path starts with the prefix.
    pub fn input_index(&self, prefix: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.path.starts_with(prefix))
    }

    /// Index of the first output whose path starts with the prefix.
    pub fn output_index(&self, prefix: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.path.starts_with(prefix))
    }
}

/// One leaf of a checkpoint manifest (`params_*.manifest.txt`).
#[derive(Clone, Debug)]
pub struct CheckpointLeaf {
    pub path: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// Parse a checkpoint manifest.
pub fn parse_checkpoint_manifest(text: &str) -> Result<Vec<CheckpointLeaf>> {
    let mut out = vec![];
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 6 || parts[0] != "leaf" || parts[2] != "f32" {
            bail!("bad checkpoint line: {line}");
        }
        out.push(CheckpointLeaf {
            path: parts[1].to_string(),
            shape: parse_shape(parts[3])?,
            offset: parts[4].parse()?,
            nbytes: parts[5].parse()?,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# artifact manifest\n\
        # kind=multihyena\n\
        in 0 0.embed f32 64,32\n\
        in 1 1 i32 4,16\n\
        in 2 2 f32 scalar\n\
        out 0 0 f32 4,16,64\n";

    #[test]
    fn parses_artifact_manifest() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.outputs.len(), 1);
        assert_eq!(m.inputs[0].shape, vec![64, 32]);
        assert_eq!(m.inputs[1].dtype, Dtype::I32);
        assert_eq!(m.inputs[2].shape, Vec::<usize>::new());
        assert_eq!(m.inputs[2].elements(), 1);
        assert_eq!(m.input_index("1"), Some(1));
    }

    #[test]
    fn rejects_out_of_order() {
        let bad = "in 1 x f32 2\nin 0 y f32 2\n";
        assert!(ArtifactManifest::parse(bad).is_err());
    }

    #[test]
    fn parses_checkpoint_manifest() {
        let text = "# ck\nleaf embed f32 4,8 0 128\nleaf ln_g f32 8 128 32\n";
        let leaves = parse_checkpoint_manifest(text).unwrap();
        assert_eq!(leaves.len(), 2);
        assert_eq!(leaves[1].offset, 128);
        assert_eq!(leaves[0].shape, vec![4, 8]);
    }

    #[test]
    fn real_artifact_manifests_parse() {
        // integration against the actual aot.py output when present
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.exists() {
            return;
        }
        for entry in std::fs::read_dir(&dir).unwrap() {
            let p = entry.unwrap().path();
            let name = p.file_name().unwrap().to_string_lossy().to_string();
            if name.ends_with(".manifest.txt") && !name.starts_with("params_") {
                ArtifactManifest::load(&p).unwrap_or_else(|e| panic!("{name}: {e}"));
            }
        }
    }
}
