//! PJRT runtime: load AOT artifacts (HLO text + manifest), own checkpoints,
//! and execute the L2 graphs from the Rust hot path.
//!
//! Interchange is HLO *text* — jax >= 0.5 emits HloModuleProtos with 64-bit
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod artifact;
pub mod checkpoint;
pub mod lm;
pub mod manifest;
pub mod trainer;

pub use artifact::Artifact;
pub use checkpoint::Checkpoint;
pub use manifest::{ArtifactManifest, TensorSpec};
