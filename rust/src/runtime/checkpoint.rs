//! Checkpoint IO: raw little-endian f32 blobs + manifests, owned by the
//! Rust launcher after aot.py writes the initial ones.

use anyhow::{bail, Context, Result};
use std::path::Path;

use super::manifest::{parse_checkpoint_manifest, CheckpointLeaf};

/// A named, shaped f32 tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub path: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros_like(&self) -> Tensor {
        Tensor {
            path: self.path.clone(),
            shape: self.shape.clone(),
            data: vec![0.0; self.data.len()],
        }
    }
}

/// An ordered set of tensors (flatten order = manifest order = PJRT order).
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub tensors: Vec<Tensor>,
}

impl Checkpoint {
    /// Load `<base>.bin` + `<base>.manifest.txt`.
    pub fn load(base: &Path) -> Result<Checkpoint> {
        let mpath = base.with_extension("manifest.txt");
        let bpath = base.with_extension("bin");
        let leaves = parse_checkpoint_manifest(
            &std::fs::read_to_string(&mpath)
                .with_context(|| format!("reading {}", mpath.display()))?,
        )?;
        let blob = std::fs::read(&bpath)
            .with_context(|| format!("reading {}", bpath.display()))?;
        let mut tensors = Vec::with_capacity(leaves.len());
        for CheckpointLeaf { path, shape, offset, nbytes } in leaves {
            if offset + nbytes > blob.len() {
                bail!("leaf {path} out of range");
            }
            if nbytes % 4 != 0 {
                bail!("leaf {path} not f32-aligned");
            }
            let mut data = vec![0f32; nbytes / 4];
            for (i, chunk) in blob[offset..offset + nbytes].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            let elems: usize = shape.iter().product::<usize>().max(1);
            if elems != data.len() {
                bail!("leaf {path}: shape/size mismatch");
            }
            tensors.push(Tensor { path, shape, data });
        }
        Ok(Checkpoint { tensors })
    }

    /// Save back as `<base>.bin` + `<base>.manifest.txt`.
    pub fn save(&self, base: &Path) -> Result<()> {
        let mut blob: Vec<u8> = vec![];
        let mut lines =
            vec!["# checkpoint manifest: leaf path, dtype, shape, byte offset, bytes".to_string()];
        for t in &self.tensors {
            let off = blob.len();
            for v in &t.data {
                blob.extend_from_slice(&v.to_le_bytes());
            }
            let shape = if t.shape.is_empty() {
                "scalar".to_string()
            } else {
                t.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
            };
            lines.push(format!("leaf {} f32 {} {} {}", t.path, shape, off, t.data.len() * 4));
        }
        std::fs::write(base.with_extension("bin"), &blob)?;
        std::fs::write(base.with_extension("manifest.txt"), lines.join("\n") + "\n")?;
        Ok(())
    }

    pub fn get(&self, path: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.path == path)
    }

    pub fn get_mut(&mut self, path: &str) -> Option<&mut Tensor> {
        self.tensors.iter_mut().find(|t| t.path == path)
    }

    /// Tensors whose path matches a prefix (e.g. one layer).
    pub fn with_prefix(&self, prefix: &str) -> Vec<&Tensor> {
        self.tensors.iter().filter(|t| t.path.starts_with(prefix)).collect()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let ck = Checkpoint {
            tensors: vec![
                Tensor { path: "a.b".into(), shape: vec![2, 3], data: vec![1.0; 6] },
                Tensor { path: "c".into(), shape: vec![], data: vec![-2.5] },
            ],
        };
        let dir = std::env::temp_dir().join("lh_ck_test");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("ck");
        ck.save(&base).unwrap();
        let back = Checkpoint::load(&base).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.get("a.b").unwrap().data, vec![1.0; 6]);
        assert_eq!(back.get("c").unwrap().data, vec![-2.5]);
        assert_eq!(back.total_params(), 7);
    }

    #[test]
    fn loads_real_aot_checkpoint_when_present() {
        let base = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/params_multihyena_tiny");
        if !base.with_extension("bin").exists() {
            return;
        }
        let ck = Checkpoint::load(&base).unwrap();
        assert!(ck.total_params() > 1000);
        assert!(ck.get("embed").is_some());
        // layers flattened with dotted paths
        assert!(ck.tensors.iter().any(|t| t.path.contains("layers.0")));
    }
}
