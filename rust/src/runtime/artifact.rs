//! PJRT artifact executor: HLO text -> compile once -> execute many.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactManifest, Dtype};

/// Host-side tensor value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(vec![x], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Value {
        assert_eq!(data.len(), shape.iter().product::<usize>().max(1));
        Value::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Value {
        assert_eq!(data.len(), shape.iter().product::<usize>().max(1));
        Value::I32(data, shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(_, s) | Value::I32(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(d, _) => Ok(d),
            _ => bail!("expected i32 value"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Value::F32(d, _) => xla::Literal::vec1(d).reshape(&dims)?,
            Value::I32(d, _) => xla::Literal::vec1(d).reshape(&dims)?,
        })
    }

    fn from_literal(lit: &xla::Literal, dtype: Dtype, shape: &[usize]) -> Result<Value> {
        Ok(match dtype {
            Dtype::F32 => Value::F32(lit.to_vec::<f32>()?, shape.to_vec()),
            Dtype::I32 => Value::I32(lit.to_vec::<i32>()?, shape.to_vec()),
        })
    }
}

/// Shared PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile `<dir>/<name>.hlo.txt` (+ manifest).
    pub fn load(&self, dir: &Path, name: &str) -> Result<Artifact> {
        let hlo = dir.join(format!("{name}.hlo.txt"));
        let man = dir.join(format!("{name}.manifest.txt"));
        let manifest = ArtifactManifest::load(&man)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Artifact { exe, manifest, name: name.to_string() })
    }
}

/// One compiled executable + its IO manifest.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: ArtifactManifest,
    pub name: String,
}

impl Artifact {
    /// Execute with host values; validates against the manifest and returns
    /// host values in manifest output order.
    pub fn execute(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest wants {}",
                self.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(&self.manifest.inputs) {
            if v.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: input {} ({}) shape {:?} != manifest {:?}",
                    self.name,
                    spec.index,
                    spec.path,
                    v.shape(),
                    spec.shape
                );
            }
            let ok = matches!(
                (v, spec.dtype),
                (Value::F32(..), Dtype::F32) | (Value::I32(..), Dtype::I32)
            );
            if !ok {
                bail!("{}: input {} dtype mismatch", self.name, spec.index);
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = tuple.to_tuple()?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest wants {}",
                self.name,
                parts.len(),
                self.manifest.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.manifest.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec.dtype, &spec.shape))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn distill_step_artifact_round_trips_and_reduces_loss() {
        // The full L3->PJRT->L2->L1 stack on the tiny distill artifact.
        let dir = artifacts_dir();
        if !dir.join("distill_step_c8_d8_l64.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::cpu().expect("pjrt cpu client");
        let art = rt.load(&dir, "distill_step_c8_d8_l64").expect("load artifact");
        let (c, d, l) = (8usize, 8usize, 64usize);
        // params: decay, theta, r_re, r_im [C, d] (manifest order 0.decay..)
        let mut rng = crate::util::Prng::new(5);
        let decay: Vec<f32> = (0..c * d).map(|i| 0.6 + 0.3 * ((i % d) as f32 / d as f32)).collect();
        let theta: Vec<f32> =
            (0..c * d).map(|i| std::f32::consts::PI * (i % d) as f32 / d as f32).collect();
        let r_re: Vec<f32> = (0..c * d).map(|_| 0.01 * rng.normal() as f32).collect();
        let r_im = vec![0.0f32; c * d];
        let zeros = vec![0.0f32; c * d];
        // target: decaying cosine filters
        let target: Vec<f32> = (0..c * l)
            .map(|i| {
                let (ch, t) = (i / l, (i % l) as f32);
                ((-0.05 * t).exp() * (0.2 * (ch as f32 + 1.0) * t).cos()) as f32
            })
            .collect();
        let cd = [c, d];
        let mk = |v: &Vec<f32>| Value::f32(v.clone(), &cd);
        let mut p = [mk(&decay), mk(&theta), mk(&r_re), mk(&r_im)];
        let mut m: Vec<Value> = (0..4).map(|_| mk(&zeros)).collect();
        let mut v: Vec<Value> = (0..4).map(|_| mk(&zeros)).collect();
        let tgt = Value::f32(target, &[c, l]);
        let mut first_loss = None;
        let mut last_loss = 0.0f32;
        for it in 0..150 {
            let mut inputs: Vec<Value> = vec![];
            inputs.extend_from_slice(&p);
            inputs.extend(m.iter().cloned());
            inputs.extend(v.iter().cloned());
            inputs.push(Value::scalar_f32(it as f32));
            inputs.push(tgt.clone());
            let out = art.execute(&inputs).expect("execute");
            assert_eq!(out.len(), 13); // 4 params + 4 m + 4 v + loss
            for i in 0..4 {
                p[i] = out[i].clone();
                m[i] = out[4 + i].clone();
                v[i] = out[8 + i].clone();
            }
            last_loss = out[12].as_f32().unwrap()[0];
            if first_loss.is_none() {
                first_loss = Some(last_loss);
            }
        }
        let first = first_loss.unwrap();
        assert!(last_loss.is_finite());
        assert!(
            last_loss < 0.5 * first,
            "distill loss should drop: {first} -> {last_loss}"
        );
    }
}
