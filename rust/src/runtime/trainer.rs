//! Training driver: owns params + Adam state as host values and steps the
//! AOT `train_step_*` artifact.  This is the L3 side of the Table 5.1 /
//! Table E.1 pre-training runs — Python only built the graph.

use anyhow::{bail, Result};
use std::path::Path;

use super::artifact::{Artifact, Runtime, Value};
use super::checkpoint::Checkpoint;

pub struct Trainer {
    train: Artifact,
    eval: Option<Artifact>,
    /// Flattened parameter leaves (manifest order).
    pub params: Vec<Value>,
    m: Vec<Value>,
    v: Vec<Value>,
    step: f32,
    pub batch: usize,
    pub seq_len: usize,
}

impl Trainer {
    /// Load `train_step_<tag>` (+ optional `eval_loss_<tag>`) and the
    /// initial checkpoint `params_<tag>`.
    pub fn new(rt: &Runtime, dir: &Path, tag: &str) -> Result<Trainer> {
        let train = rt.load(dir, &format!("train_step_{tag}"))?;
        let eval = rt.load(dir, &format!("eval_loss_{tag}")).ok();
        let ck = Checkpoint::load(&dir.join(format!("params_{tag}")))?;
        // manifest inputs: params (0.*), m (1.*), v (2.*), step, tokens,
        // targets, mask
        let n_leaves = train
            .manifest
            .inputs
            .iter()
            .filter(|s| s.path.starts_with("0."))
            .count();
        if n_leaves != ck.tensors.len() {
            bail!(
                "checkpoint has {} leaves, manifest wants {n_leaves}",
                ck.tensors.len()
            );
        }
        let params: Vec<Value> = ck
            .tensors
            .iter()
            .map(|t| Value::f32(t.data.clone(), &t.shape))
            .collect();
        let zeros: Vec<Value> = ck
            .tensors
            .iter()
            .map(|t| Value::f32(vec![0.0; t.data.len()], &t.shape))
            .collect();
        let tok_spec = &train.manifest.inputs[3 * n_leaves + 1];
        let (batch, seq_len) = (tok_spec.shape[0], tok_spec.shape[1]);
        Ok(Trainer {
            train,
            eval,
            params,
            m: zeros.clone(),
            v: zeros,
            step: 0.0,
            batch,
            seq_len,
        })
    }

    /// One optimizer step; returns the training loss.
    pub fn step(&mut self, tokens: &[i32], targets: &[i32], mask: &[f32]) -> Result<f32> {
        let bt = [self.batch, self.seq_len];
        let mut inputs: Vec<Value> = Vec::with_capacity(3 * self.params.len() + 4);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());
        inputs.push(Value::scalar_f32(self.step));
        inputs.push(Value::i32(tokens.to_vec(), &bt));
        inputs.push(Value::i32(targets.to_vec(), &bt));
        inputs.push(Value::f32(mask.to_vec(), &bt));
        let out = self.train.execute(&inputs)?;
        let n = self.params.len();
        for i in 0..n {
            self.params[i] = out[i].clone();
            self.m[i] = out[n + i].clone();
            self.v[i] = out[2 * n + i].clone();
        }
        self.step += 1.0;
        Ok(out[3 * n].as_f32()?[0])
    }

    /// Held-out loss via the eval artifact.
    pub fn eval(&self, tokens: &[i32], targets: &[i32], mask: &[f32]) -> Result<f32> {
        let eval = match &self.eval {
            Some(e) => e,
            None => bail!("no eval artifact loaded"),
        };
        let bt = [self.batch, self.seq_len];
        let mut inputs: Vec<Value> = Vec::with_capacity(self.params.len() + 3);
        inputs.extend(self.params.iter().cloned());
        inputs.push(Value::i32(tokens.to_vec(), &bt));
        inputs.push(Value::i32(targets.to_vec(), &bt));
        inputs.push(Value::f32(mask.to_vec(), &bt));
        let out = eval.execute(&inputs)?;
        Ok(out[0].as_f32()?[0])
    }

    /// Export the current params as a checkpoint.
    pub fn checkpoint(&self, reference: &Checkpoint) -> Checkpoint {
        let mut ck = reference.clone();
        for (t, v) in ck.tensors.iter_mut().zip(&self.params) {
            if let Value::F32(d, _) = v {
                t.data = d.clone();
            }
        }
        ck
    }

    pub fn steps_done(&self) -> usize {
        self.step as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Corpus;

    #[test]
    fn tiny_train_step_reduces_loss() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("train_step_multihyena_tiny.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let mut tr = Trainer::new(&rt, &dir, "multihyena_tiny").unwrap();
        assert_eq!(tr.batch, 4);
        assert_eq!(tr.seq_len, 64);
        let mut corpus = Corpus::new(64, 4, 1);
        let mask = vec![1.0f32; tr.batch * tr.seq_len];
        let mut first = 0.0;
        let mut last = 0.0;
        for i in 0..30 {
            let (tok, tgt) = corpus.batch(tr.batch, tr.seq_len);
            let loss = tr.step(&tok, &tgt, &mask).unwrap();
            if i == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(last < first, "loss should fall: {first} -> {last}");
        // eval path works too
        let (tok, tgt) = corpus.batch(tr.batch, tr.seq_len);
        let ev = tr.eval(&tok, &tgt, &mask).unwrap();
        assert!(ev.is_finite());
    }
}
