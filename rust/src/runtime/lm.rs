//! Served model: the prefill + decode AOT artifacts, the trained weights,
//! and the distilled modal parameters — everything the coordinator needs to
//! generate tokens with Python fully out of the loop.

use anyhow::{bail, Result};
use std::path::Path;

use super::artifact::{Artifact, Runtime, Value};
use super::checkpoint::Checkpoint;
use crate::ssm::ModalSsm;

/// Shapes recovered from the decode manifest.
#[derive(Clone, Debug)]
pub struct ServedShape {
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub n_layer: usize,
    pub d_model: usize,
    pub heads: usize,
    pub d_state: usize,
    pub sc_width: usize, // 3*D
    pub sc_tail: usize,  // short_kw - 1
}

pub struct ServedModel {
    prefill: Artifact,
    decode: Artifact,
    params: Vec<Value>,
    /// Modal leaves in manifest (sorted-key) order:
    /// h0, lam_im, lam_re, r_im, r_re.
    modal: Vec<Value>,
    pub shape: ServedShape,
    // live generation state (host-resident between steps)
    x_re: Vec<f32>,
    x_im: Vec<f32>,
    sc: Vec<f32>,
    pub last_tokens: Vec<i32>,
}

impl ServedModel {
    /// Load `prefill_<tag>` + `decode_<tag>` + `params_<tag>`.
    pub fn new(rt: &Runtime, dir: &Path, tag: &str) -> Result<ServedModel> {
        let prefill = rt.load(dir, &format!("prefill_{tag}"))?;
        let decode = rt.load(dir, &format!("decode_{tag}"))?;
        let ck = Checkpoint::load(&dir.join(format!("params_{tag}")))?;
        let params: Vec<Value> = ck
            .tensors
            .iter()
            .map(|t| Value::f32(t.data.clone(), &t.shape))
            .collect();
        // recover shapes from the decode manifest
        let n_p = decode
            .manifest
            .inputs
            .iter()
            .filter(|s| s.path.starts_with("0."))
            .count();
        let n_modal = decode
            .manifest
            .inputs
            .iter()
            .filter(|s| s.path.starts_with("1."))
            .count();
        if n_modal != 5 {
            bail!("expected 5 modal leaves, found {n_modal}");
        }
        let tok = &decode.manifest.inputs[n_p + n_modal];
        let xre = &decode.manifest.inputs[n_p + n_modal + 1];
        let scb = &decode.manifest.inputs[n_p + n_modal + 3];
        let lam_spec = decode
            .manifest
            .inputs
            .iter()
            .find(|s| s.path == "1.lam_re")
            .expect("modal lam_re leaf");
        let logits_spec = &decode.manifest.outputs[0];
        let ptok = prefill
            .manifest
            .inputs
            .iter()
            .find(|s| s.path == "2")
            .expect("prefill tokens");
        let shape = ServedShape {
            batch: tok.shape[0],
            seq_len: ptok.shape[1],
            vocab: logits_spec.shape[1],
            n_layer: xre.shape[1],
            d_model: xre.shape[2],
            heads: lam_spec.shape[1],
            d_state: xre.shape[3],
            sc_width: scb.shape[2],
            sc_tail: scb.shape[3],
        };
        let b = shape.batch;
        let state_len = b * shape.n_layer * shape.d_model * shape.d_state;
        let sc_len = b * shape.n_layer * shape.sc_width * shape.sc_tail;
        let modal = default_modal(&shape);
        Ok(ServedModel {
            prefill,
            decode,
            params,
            modal,
            x_re: vec![0.0; state_len],
            x_im: vec![0.0; state_len],
            sc: vec![0.0; sc_len],
            last_tokens: vec![0; b],
            shape,
        })
    }

    /// Install distilled filters: `systems[layer][head]`.
    pub fn set_modal(&mut self, systems: &[Vec<ModalSsm>]) -> Result<()> {
        let s = &self.shape;
        if systems.len() != s.n_layer || systems.iter().any(|l| l.len() != s.heads) {
            bail!("expected {}x{} modal systems", s.n_layer, s.heads);
        }
        let n = s.n_layer * s.heads * s.d_state;
        let mut lam_re = vec![0f32; n];
        let mut lam_im = vec![0f32; n];
        let mut r_re = vec![0f32; n];
        let mut r_im = vec![0f32; n];
        let mut h0 = vec![0f32; s.n_layer * s.heads];
        for (li, layer) in systems.iter().enumerate() {
            for (hi, sys) in layer.iter().enumerate() {
                if sys.order() != s.d_state {
                    bail!("system order {} != artifact d_state {}", sys.order(), s.d_state);
                }
                let base = (li * s.heads + hi) * s.d_state;
                for (k, (p, r)) in sys.poles.iter().zip(&sys.residues).enumerate() {
                    lam_re[base + k] = p.re as f32;
                    lam_im[base + k] = p.im as f32;
                    r_re[base + k] = r.re as f32;
                    r_im[base + k] = r.im as f32;
                }
                h0[li * s.heads + hi] = sys.h0 as f32;
            }
        }
        let lmd = [s.n_layer, s.heads, s.d_state];
        let lm2 = [s.n_layer, s.heads];
        self.modal = vec![
            Value::f32(h0, &lm2),
            Value::f32(lam_im, &lmd),
            Value::f32(lam_re, &lmd),
            Value::f32(r_im, &lmd),
            Value::f32(r_re, &lmd),
        ];
        Ok(())
    }

    /// Prefill the whole batch; prompts are right-padded internally.
    /// Returns the first greedy token per row.
    pub fn prefill_batch(&mut self, prompts: &[Vec<i32>]) -> Result<Vec<i32>> {
        let s = self.shape.clone();
        if prompts.len() != s.batch {
            bail!("expected {} prompts", s.batch);
        }
        let mut tokens = vec![0i32; s.batch * s.seq_len];
        let mut lengths = vec![0i32; s.batch];
        for (b, p) in prompts.iter().enumerate() {
            if p.is_empty() || p.len() > s.seq_len {
                bail!("prompt length {} out of range 1..{}", p.len(), s.seq_len);
            }
            tokens[b * s.seq_len..b * s.seq_len + p.len()].copy_from_slice(p);
            lengths[b] = p.len() as i32;
        }
        let mut inputs: Vec<Value> = self.params.clone();
        inputs.extend(self.modal.iter().cloned());
        inputs.push(Value::i32(tokens, &[s.batch, s.seq_len]));
        inputs.push(Value::i32(lengths, &[s.batch]));
        let out = self.prefill.execute(&inputs)?;
        let logits = out[0].as_f32()?;
        self.x_re = out[1].as_f32()?.to_vec();
        self.x_im = out[2].as_f32()?.to_vec();
        self.sc = out[3].as_f32()?.to_vec();
        let next: Vec<i32> = (0..s.batch)
            .map(|b| argmax_f32(&logits[b * s.vocab..(b + 1) * s.vocab]) as i32)
            .collect();
        self.last_tokens = next.clone();
        Ok(next)
    }

    /// Replace the model weights (e.g. with a trained checkpoint).
    pub fn set_params(&mut self, params: Vec<Value>) {
        assert_eq!(params.len(), self.params.len(), "leaf count mismatch");
        self.params = params;
    }

    /// One decode step returning the raw logits [B*V] (teacher-forcing
    /// callers overwrite `last_tokens` before each call); also advances
    /// `last_tokens` greedily for plain generation.
    pub fn decode_step_logits(&mut self) -> Result<Vec<f32>> {
        let s = self.shape.clone();
        let state_shape = [s.batch, s.n_layer, s.d_model, s.d_state];
        let sc_shape = [s.batch, s.n_layer, s.sc_width, s.sc_tail];
        let mut inputs: Vec<Value> = self.params.clone();
        inputs.extend(self.modal.iter().cloned());
        inputs.push(Value::i32(self.last_tokens.clone(), &[s.batch]));
        inputs.push(Value::f32(self.x_re.clone(), &state_shape));
        inputs.push(Value::f32(self.x_im.clone(), &state_shape));
        inputs.push(Value::f32(self.sc.clone(), &sc_shape));
        let out = self.decode.execute(&inputs)?;
        let logits = out[0].as_f32()?.to_vec();
        self.x_re = out[1].as_f32()?.to_vec();
        self.x_im = out[2].as_f32()?.to_vec();
        self.sc = out[3].as_f32()?.to_vec();
        for b in 0..s.batch {
            self.last_tokens[b] =
                argmax_f32(&logits[b * s.vocab..(b + 1) * s.vocab]) as i32;
        }
        Ok(logits)
    }

    /// One decode step for the whole batch (greedy feedback).
    pub fn decode_step(&mut self) -> Result<Vec<i32>> {
        let s = self.shape.clone();
        let state_shape = [s.batch, s.n_layer, s.d_model, s.d_state];
        let sc_shape = [s.batch, s.n_layer, s.sc_width, s.sc_tail];
        let mut inputs: Vec<Value> = self.params.clone();
        inputs.extend(self.modal.iter().cloned());
        inputs.push(Value::i32(self.last_tokens.clone(), &[s.batch]));
        inputs.push(Value::f32(self.x_re.clone(), &state_shape));
        inputs.push(Value::f32(self.x_im.clone(), &state_shape));
        inputs.push(Value::f32(self.sc.clone(), &sc_shape));
        let out = self.decode.execute(&inputs)?;
        let logits = out[0].as_f32()?;
        self.x_re = out[1].as_f32()?.to_vec();
        self.x_im = out[2].as_f32()?.to_vec();
        self.sc = out[3].as_f32()?.to_vec();
        let next: Vec<i32> = (0..s.batch)
            .map(|b| argmax_f32(&logits[b * s.vocab..(b + 1) * s.vocab]) as i32)
            .collect();
        self.last_tokens = next.clone();
        Ok(next)
    }

    /// Merge prefilled state rows from another prefill result into chosen
    /// slots — the continuous-batching primitive (batch rows are
    /// independent in every op of the graph).
    pub fn adopt_rows(&mut self, other: &ServedModel, rows: &[(usize, usize)]) {
        let s = &self.shape;
        let state_row = s.n_layer * s.d_model * s.d_state;
        let sc_row = s.n_layer * s.sc_width * s.sc_tail;
        for &(src, dst) in rows {
            self.x_re[dst * state_row..(dst + 1) * state_row]
                .copy_from_slice(&other.x_re[src * state_row..(src + 1) * state_row]);
            self.x_im[dst * state_row..(dst + 1) * state_row]
                .copy_from_slice(&other.x_im[src * state_row..(src + 1) * state_row]);
            self.sc[dst * sc_row..(dst + 1) * sc_row]
                .copy_from_slice(&other.sc[src * sc_row..(src + 1) * sc_row]);
            self.last_tokens[dst] = other.last_tokens[src];
        }
    }

    /// Snapshot one slot's generation state (continuous batching: busy rows
    /// survive whole-batch prefills of other slots).
    pub fn save_row(&self, row: usize) -> RowState {
        let s = &self.shape;
        let state_row = s.n_layer * s.d_model * s.d_state;
        let sc_row = s.n_layer * s.sc_width * s.sc_tail;
        RowState {
            x_re: self.x_re[row * state_row..(row + 1) * state_row].to_vec(),
            x_im: self.x_im[row * state_row..(row + 1) * state_row].to_vec(),
            sc: self.sc[row * sc_row..(row + 1) * sc_row].to_vec(),
            last: self.last_tokens[row],
        }
    }

    /// Restore a snapshot into a slot.
    pub fn restore_row(&mut self, row: usize, saved: &RowState) {
        let s = &self.shape;
        let state_row = s.n_layer * s.d_model * s.d_state;
        let sc_row = s.n_layer * s.sc_width * s.sc_tail;
        self.x_re[row * state_row..(row + 1) * state_row].copy_from_slice(&saved.x_re);
        self.x_im[row * state_row..(row + 1) * state_row].copy_from_slice(&saved.x_im);
        self.sc[row * sc_row..(row + 1) * sc_row].copy_from_slice(&saved.sc);
        self.last_tokens[row] = saved.last;
    }

    /// Zero the generation state of one slot.
    pub fn clear_row(&mut self, row: usize) {
        let s = &self.shape;
        let state_row = s.n_layer * s.d_model * s.d_state;
        let sc_row = s.n_layer * s.sc_width * s.sc_tail;
        self.x_re[row * state_row..(row + 1) * state_row].fill(0.0);
        self.x_im[row * state_row..(row + 1) * state_row].fill(0.0);
        self.sc[row * sc_row..(row + 1) * sc_row].fill(0.0);
        self.last_tokens[row] = 0;
    }

    /// Per-sequence recurrent state bytes (the O(d) memory of Lemma 2.2).
    pub fn state_bytes_per_seq(&self) -> u64 {
        let s = &self.shape;
        ((2 * s.n_layer * s.d_model * s.d_state + s.n_layer * s.sc_width * s.sc_tail) * 4)
            as u64
    }
}

/// Saved per-slot generation state.  Fields are public so the coordinator's
/// session layer can lift a row into a portable
/// [`crate::session::SessionState`] blob and back.
#[derive(Clone, Debug)]
pub struct RowState {
    pub x_re: Vec<f32>,
    pub x_im: Vec<f32>,
    pub sc: Vec<f32>,
    pub last: i32,
}

fn default_modal(s: &ServedShape) -> Vec<Value> {
    let lmd = [s.n_layer, s.heads, s.d_state];
    let lm2 = [s.n_layer, s.heads];
    let n = s.n_layer * s.heads * s.d_state;
    vec![
        Value::f32(vec![1.0; s.n_layer * s.heads], &lm2), // h0 = identity tap
        Value::f32(vec![0.0; n], &lmd),
        Value::f32(vec![0.0; n], &lmd),
        Value::f32(vec![0.0; n], &lmd),
        Value::f32(vec![0.0; n], &lmd),
    ]
}

fn argmax_f32(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::MIN;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn served_model_generates_with_tiny_artifacts() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("decode_multihyena_tiny.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let mut lm = ServedModel::new(&rt, &dir, "multihyena_tiny").unwrap();
        assert_eq!(lm.shape.batch, 4);
        assert_eq!(lm.shape.d_state, 8);
        let prompts: Vec<Vec<i32>> =
            (0..4).map(|b| vec![1 + b as i32, 2, 3, 4, 5]).collect();
        let first = lm.prefill_batch(&prompts).unwrap();
        assert!(first.iter().all(|&t| (t as usize) < lm.shape.vocab));
        for _ in 0..3 {
            let toks = lm.decode_step().unwrap();
            assert!(toks.iter().all(|&t| (t as usize) < lm.shape.vocab));
        }
        // row ops
        lm.clear_row(1);
        let snapshot = lm.last_tokens.clone();
        assert_eq!(snapshot[1], 0);
    }
}
