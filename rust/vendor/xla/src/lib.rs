//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links `xla_extension` (a multi-gigabyte native bundle)
//! that the offline build image does not carry. This stub exposes the exact
//! API surface `laughing_hyena::runtime` consumes so the workspace compiles
//! and tests everywhere; every entry point that would need the native
//! runtime returns [`Error`] with an explanatory message instead.
//!
//! The gate is [`PjRtClient::cpu`]: it fails immediately, and every caller
//! in the repository constructs the client before loading or executing
//! artifacts, so no stubbed data path is ever reachable. Runtime tests gate
//! themselves on the presence of `artifacts/` and skip cleanly.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversion into
/// `anyhow::Error` (it implements [`std::error::Error`]).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT runtime unavailable (offline xla stub; install the \
             xla_extension bundle and swap rust/vendor/xla for the real bindings)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (dense tensor value crossing the PJRT boundary).
#[derive(Clone, Debug)]
pub struct Literal {
    elems: usize,
}

impl Literal {
    /// Build a rank-1 literal from a host slice (shape-only in the stub).
    pub fn vec1<T: Copy>(data: &[T]) -> Literal {
        Literal { elems: data.len() }
    }

    /// Reinterpret the literal under new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if !dims.is_empty() && want as usize != self.elems {
            return Err(Error(format!(
                "reshape: {} elements into {dims:?}",
                self.elems
            )));
        }
        Ok(self.clone())
    }

    /// Copy the literal out to a host vector — unreachable in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    /// Destructure a tuple literal — unreachable in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the stub's gate: it always
/// fails, so nothing downstream ever executes.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client — always fails in the offline stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Platform name of the backing runtime.
    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    /// Compile a computation — unreachable (no client can exist).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file — unreachable (no client can exist).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host arguments — unreachable (no executable can exist).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer to a host literal — unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn literals_carry_shape_only() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        assert!(Literal::vec1(&[1i32]).reshape(&[5]).is_err());
    }
}
