//! Minimal, API-compatible subset of the `anyhow` crate.
//!
//! The offline build image ships no crates.io registry, so this vendored
//! shim provides exactly the surface the repository uses:
//!
//! * [`Error`] — an opaque error that any `std::error::Error` converts into,
//!   carrying the full source chain as text;
//! * [`Result<T>`] with the `Error` default;
//! * the [`Context`] extension trait for `Result` and `Option`;
//! * the [`anyhow!`] and [`bail!`] macros.
//!
//! Formatting matches anyhow's conventions: `{}` prints the outermost
//! message, `{:#}` prints the chain joined with `": "`, and `{:?}` prints a
//! multi-line report with a `Caused by:` section.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Opaque error: a message plus the stringified source chain.
pub struct Error {
    msg: String,
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), chain: Vec::new() }
    }

    /// Push `context` in front of the current message (the anyhow
    /// `.context()` semantics: newest context is the outermost message).
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        let inner = std::mem::replace(&mut self.msg, context.to_string());
        self.chain.insert(0, inner);
        self
    }

    /// The stringified error chain, outermost first (message excluded).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in &self.chain {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that keeps this blanket conversion coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let msg = e.to_string();
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg, chain }
    }
}

/// Extension trait attaching context to `Result` and `Option` errors.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily computed context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn from_std_error_keeps_message() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_wraps_outermost() {
        let r: Result<()> = Err(io_err()).context("opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("nothing here");
        assert_eq!(r.unwrap_err().to_string(), "nothing here");
        let ok: Result<i32> = Some(3).context("unused");
        assert_eq!(ok.unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: usize) -> Result<()> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("unlucky {n}");
            }
            Ok(())
        }
        assert!(fails(2).is_ok());
        assert_eq!(fails(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(fails(11).unwrap_err().to_string(), "n too big: 11");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }
}
