# CI-style entry points. `make verify` is the tier-1 gate; `make help`
# lists everything.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: help build test verify ci chaos metrics load crash trace lint doc bench bench-decode bench-smoke serve-demo loadgen-demo artifacts clean

help:
	@echo "targets:"
	@echo "  build        cargo build --release"
	@echo "  test         cargo test -q"
	@echo "  verify       tier-1 gate: build + test"
	@echo "  ci           full gate: build + test (with and without --features simd)"
	@echo "               + bounded chaos/metrics/load/crash/trace suites + clippy"
	@echo "               + docs (warnings denied) + decode bench smoke"
	@echo "  chaos        fault-injection suite (tests/serve_chaos.rs) under a"
	@echo "               wall-clock bound; loopback-only, port-0, sandbox-safe"
	@echo "  metrics      observability suite: obs unit tests + the live-cluster"
	@echo "               /metrics scrape integration test (tests/serve_metrics.rs)"
	@echo "  load         chaos-under-load harness (tests/serve_load.rs): 200-session"
	@echo "               loadgen over the wire front door with a mid-run shard kill,"
	@echo "               revival, bulk drain, typed-shed and TTL-resume acceptance"
	@echo "  crash        crash-durability harness (tests/serve_crash.rs): router kill"
	@echo "               mid-load + journal-replay restart, full-cluster cold restart,"
	@echo "               torn-tail/corrupt-record refusal; wall-clock-bounded"
	@echo "  trace        distributed-tracing harness (tests/serve_trace.rs): cross-hop"
	@echo "               span-tree join over the wire, resurrection/retry annotations,"
	@echo "               /trace/<id> lookup, sampled engine profiling; both feature legs"
	@echo "  lint         cargo clippy with warnings denied"
	@echo "  doc          cargo doc --no-deps"
	@echo "  bench        all bench suites (distillation, substrates,"
	@echo "               generation, coordinator, session, decode)"
	@echo "  bench-decode decode hot-path bench with the 2x throughput gate;"
	@echo "               rewrites BENCH_decode.json at the repo root"
	@echo "  bench-smoke  1-iteration decode bench (--features simd, no gate,"
	@echo "               no file writes) so bench code cannot rot"
	@echo "  serve-demo   2-shard serving cluster on loopback sockets with a"
	@echo "               live mid-conversation session migration"
	@echo "  loadgen-demo closed-loop loadgen against an in-process 2-shard cluster;"
	@echo "               writes BENCH_load.json at the repo root"
	@echo "  artifacts    lower the L2 graphs to HLO under rust/artifacts/ (needs JAX)"
	@echo "  clean        cargo clean + remove results/"

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# tier-1 gate: build + full test suite
verify: build test

# full CI chain: tier-1 (default features AND the simd intrinsics path)
# plus the bounded chaos suite, clippy, rustdoc with warnings denied, and
# the decode bench smoke.  `cargo test` includes the serve-layer loopback
# integration tests (tests/serve_router.rs, tests/serve_chaos.rs): router
# + shard servers on 127.0.0.1 with port-0 auto-assign, so everything is
# sandbox-safe; clippy covers serve/ via --all-targets.
ci:
	$(CARGO) build --release
	$(CARGO) build --release --features simd
	$(CARGO) test -q
	$(CARGO) test -q --features simd
	$(MAKE) chaos
	$(MAKE) metrics
	$(MAKE) load
	$(MAKE) crash
	$(MAKE) trace
	$(CARGO) clippy --all-targets -- -D warnings
	$(CARGO) clippy --all-targets --features simd -- -D warnings
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps
	$(MAKE) bench-smoke

# the fault-injection suite, explicitly wall-clock-bounded: every fault is
# injected deterministically (no sleep-and-hope races), so a hang here is
# a real recovery-path bug — fail it rather than wedge CI.
chaos:
	timeout 420 $(CARGO) test -q --test serve_chaos

# the observability suite: histogram/registry/trace unit tests plus the
# live-cluster scrape integration test (2 shards + front door, HTTP GET
# /metrics over a real loopback socket, mid-generation scrape included).
# Wall-clock-bounded like chaos: a wedged scrape is a routing-lock bug,
# not something to wait out.  Also the fast loop for obs-layer work.
metrics:
	$(CARGO) test -q --lib obs::
	timeout 420 $(CARGO) test -q --test serve_metrics

# the overload/robustness acceptance harness: deterministic loadgen
# workload (rust/src/loadgen.rs) over real loopback wire connections with
# kill/revive/drain chaos underneath, exactly-once bit-identical delivery
# checked against an uninterrupted baseline.  Wall-clock-bounded: a hang
# is an admission/recovery deadlock, not something to wait out.
load:
	timeout 420 $(CARGO) test -q --test serve_load

# the crash-durability acceptance harness: a router "process death" (the
# instance is dropped mid-load, its in-memory mirror gone) followed by a
# journal-replay restart, a full-cluster cold restart from --journal-dir,
# and torn-tail / flipped-bit refusal checks — every acked turn must
# resume bit-identically, exactly once, against an uninterrupted
# reference.  Wall-clock-bounded like the other fault suites.
crash:
	timeout 420 $(CARGO) test -q --test serve_crash

# the distributed-tracing acceptance harness: traced wire turns whose
# span reports must join front/router/shard/coordinator/engine into one
# clock-skew-immune tree, carry retry/resurrection annotations under an
# injected shard kill, serve over GET /trace/<id>, and feed the sampled
# lh_engine_* stage histograms.  Runs on both feature legs because the
# profiled engine path has a SIMD twin that must stay span-identical.
trace:
	timeout 420 $(CARGO) test -q --test serve_trace
	timeout 420 $(CARGO) test -q --test serve_trace --features simd

# 1-iteration run of the decode bench (keeps its correctness cross-checks,
# skips the gate and the BENCH_decode.json/CSV writes): proves the bench
# still compiles and agrees without touching the recorded perf point.
# Built with --features simd so the intrinsics path stays exercised.
bench-smoke:
	DECODE_BENCH_SMOKE=1 $(CARGO) bench --bench decode --features simd

# the 2-shard quickstart: router + 2 in-process shard servers over
# loopback sockets, 4 sessions x 3 turns, one live migration in between
serve-demo:
	$(CARGO) run --release -- serve --shards 2 --sessions 4 --turns 3 --migrate

# closed-loop loadgen demo against an in-process 2-shard cluster; writes
# BENCH_load.json at the repo root
loadgen-demo:
	$(CARGO) run --release -- loadgen --shards 2 --sessions 16 --turns 3

lint:
	$(CARGO) clippy --all-targets -- -D warnings

doc:
	$(CARGO) doc --no-deps

bench:
	$(CARGO) bench --bench distillation
	$(CARGO) bench --bench substrates
	$(CARGO) bench --bench generation
	$(CARGO) bench --bench coordinator
	$(CARGO) bench --bench session
	$(CARGO) bench --bench decode

# decode hot-path throughput with the regression gate (fused+pooled must
# reach 2x the unfused serial baseline somewhere on the batch sweep);
# emits BENCH_decode.json (repo root) + results/bench_decode.csv.  Runs
# with --features simd so the recorded point carries the SIMD delta (the
# scalar fallback is measured in the same run via the forced-scalar pass).
bench-decode:
	DECODE_BENCH_GATE=1 $(CARGO) bench --bench decode --features simd

# Lower the L2 graphs to HLO artifacts under rust/artifacts/ (needs JAX).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../rust/artifacts

clean:
	$(CARGO) clean
	rm -rf results
