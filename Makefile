# CI-style entry points. `make verify` is the tier-1 gate.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test verify doc bench artifacts clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# tier-1 gate: build + full test suite
verify: build test

doc:
	$(CARGO) doc --no-deps

bench:
	$(CARGO) bench --bench distillation
	$(CARGO) bench --bench substrates
	$(CARGO) bench --bench generation
	$(CARGO) bench --bench coordinator

# Lower the L2 graphs to HLO artifacts under rust/artifacts/ (needs JAX).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../rust/artifacts

clean:
	$(CARGO) clean
	rm -rf results
