# CI-style entry points. `make verify` is the tier-1 gate; `make help`
# lists everything.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: help build test verify ci doc bench artifacts clean

help:
	@echo "targets:"
	@echo "  build      cargo build --release"
	@echo "  test       cargo test -q"
	@echo "  verify     tier-1 gate: build + test"
	@echo "  ci         full gate: build + test + docs with warnings denied"
	@echo "  doc        cargo doc --no-deps"
	@echo "  bench      all bench suites (distillation, substrates,"
	@echo "             generation, coordinator, session)"
	@echo "  artifacts  lower the L2 graphs to HLO under rust/artifacts/ (needs JAX)"
	@echo "  clean      cargo clean + remove results/"

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# tier-1 gate: build + full test suite
verify: build test

# full CI chain: tier-1 plus rustdoc with warnings denied
ci:
	$(CARGO) build --release
	$(CARGO) test -q
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

doc:
	$(CARGO) doc --no-deps

bench:
	$(CARGO) bench --bench distillation
	$(CARGO) bench --bench substrates
	$(CARGO) bench --bench generation
	$(CARGO) bench --bench coordinator
	$(CARGO) bench --bench session

# Lower the L2 graphs to HLO artifacts under rust/artifacts/ (needs JAX).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../rust/artifacts

clean:
	$(CARGO) clean
	rm -rf results
