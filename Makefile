# CI-style entry points. `make verify` is the tier-1 gate; `make help`
# lists everything.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: help build test verify ci lint doc bench bench-decode artifacts clean

help:
	@echo "targets:"
	@echo "  build        cargo build --release"
	@echo "  test         cargo test -q"
	@echo "  verify       tier-1 gate: build + test"
	@echo "  ci           full gate: build + test + clippy + docs, warnings denied"
	@echo "  lint         cargo clippy with warnings denied"
	@echo "  doc          cargo doc --no-deps"
	@echo "  bench        all bench suites (distillation, substrates,"
	@echo "               generation, coordinator, session, decode)"
	@echo "  bench-decode decode hot-path bench with the 2x throughput gate;"
	@echo "               rewrites BENCH_decode.json at the repo root"
	@echo "  artifacts    lower the L2 graphs to HLO under rust/artifacts/ (needs JAX)"
	@echo "  clean        cargo clean + remove results/"

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# tier-1 gate: build + full test suite
verify: build test

# full CI chain: tier-1 plus clippy and rustdoc with warnings denied
ci:
	$(CARGO) build --release
	$(CARGO) test -q
	$(CARGO) clippy --all-targets -- -D warnings
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

lint:
	$(CARGO) clippy --all-targets -- -D warnings

doc:
	$(CARGO) doc --no-deps

bench:
	$(CARGO) bench --bench distillation
	$(CARGO) bench --bench substrates
	$(CARGO) bench --bench generation
	$(CARGO) bench --bench coordinator
	$(CARGO) bench --bench session
	$(CARGO) bench --bench decode

# decode hot-path throughput with the regression gate (fused+pooled must
# reach 2x the unfused serial baseline somewhere on the batch sweep);
# emits BENCH_decode.json (repo root) + results/bench_decode.csv
bench-decode:
	DECODE_BENCH_GATE=1 $(CARGO) bench --bench decode

# Lower the L2 graphs to HLO artifacts under rust/artifacts/ (needs JAX).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../rust/artifacts

clean:
	$(CARGO) clean
	rm -rf results
