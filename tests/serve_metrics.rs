//! Loopback integration test of the cluster observability layer: a
//! 2-shard cluster behind a [`FrontServer`] whose HTTP sibling listener
//! is scraped over a real socket while the cluster serves traffic.
//!
//! The acceptance invariants:
//!
//! * `GET /metrics` on a live cluster returns Prometheus text carrying
//!   the **merged** TTFT/TPOT histograms (shard samples summed
//!   bucket-exactly, `_count` equal to the total turns served), the
//!   per-shard breaker states, and the router's migration counters;
//! * a scrape issued **mid-generation** (a streamed turn held open by an
//!   injected token-stream delay) waits out the in-flight turn and then
//!   succeeds — the turn's stream is never cut and the scrape observes
//!   the completed request;
//! * malformed, oversized and non-GET requests get typed HTTP errors
//!   (400/431/405) and never take the endpoint down.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use laughing_hyena::config::ServeConfig;
use laughing_hyena::engine::LmShape;
use laughing_hyena::serve::wire;
use laughing_hyena::serve::{
    BreakerConfig, FaultAction, FaultPlan, Frame, FrontConfig, FrontServer, Point, Router, Rule,
    ShardServer,
};

/// Shared seed: every shard carries identical weights, the precondition
/// for cross-shard migration (and for migrating mid-test here).
const SEED: u64 = 11;

fn cfg() -> ServeConfig {
    ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
}

fn shape() -> LmShape {
    LmShape::bench("nano").unwrap()
}

/// N native shards behind a front server, with a fault plan threaded in
/// and the background prober disabled (tests drive probes by hand so
/// breaker counters stay deterministic).
fn launch(n: usize) -> (Vec<ShardServer>, FrontServer, Arc<FaultPlan>) {
    let shape = shape();
    let shards: Vec<ShardServer> =
        (0..n).map(|_| ShardServer::spawn_native(&shape, 2, SEED, cfg()).unwrap()).collect();
    let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
    let faults = Arc::new(FaultPlan::new());
    let router = Router::new_with(&addrs, BreakerConfig::default(), Some(faults.clone())).unwrap();
    let front =
        FrontServer::spawn(
            router,
            FrontConfig { max_inflight: 4, probe_interval: None, ..FrontConfig::default() },
        )
        .unwrap();
    (shards, front, faults)
}

/// One blocking HTTP/1.1 exchange: write the request, half-close, read
/// the full response, return (status, body).
fn http_get_raw(addr: SocketAddr, raw: &[u8]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    s.write_all(raw).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("unparseable status line in {text:?}"));
    let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    http_get_raw(addr, format!("GET {path} HTTP/1.1\r\nhost: t\r\n\r\n").as_bytes())
}

/// One wire-level turn through the front door: connect, swallow the
/// greeting, submit, collect the streamed tokens until `Done`.
fn front_turn(addr: SocketAddr, sid: u64, delta: Vec<i32>, max_new: u32) -> Vec<i32> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    match wire::read_frame(&mut s).unwrap() {
        Frame::Hello { .. } => {}
        other => panic!("expected Hello greeting, got {other:?}"),
    }
    wire::write_frame(
        &mut s,
        &Frame::SubmitInSession {
            session: sid,
            strict: false,
            max_new,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta,
        },
    )
    .unwrap();
    let mut toks = Vec::new();
    loop {
        match wire::read_frame(&mut s).unwrap() {
            Frame::Token { token } => toks.push(token),
            Frame::Done { .. } => return toks,
            other => panic!("expected Token/Done, got {other:?}"),
        }
    }
}

/// The acceptance scrape: drive 4 sessions x 2 turns plus one live
/// migration and a post-migration turn, then `GET /metrics` and check
/// the Prometheus text carries the merged latency histograms, both
/// breaker states and the migration counters — with `/admin` and
/// `/traces` serving the same cluster.
#[test]
fn live_two_shard_scrape_merges_hists_breakers_and_migrations() {
    let (shards, front, _faults) = launch(2);
    let addr = front.addr();
    // 4 sessions x 2 turns over the wire: 8 requests spread across both
    // shards by consistent hashing
    for t in 0..2 {
        for sid in 0..4u64 {
            let toks = front_turn(addr, sid, vec![1 + (sid + t) as i32; 5], 3);
            assert_eq!(toks.len(), 3);
        }
    }
    // live-migrate session 0 and serve one more turn on its new home
    let router = front.router();
    {
        let mut r = router.lock().unwrap();
        let home = r.shard_of(0).unwrap();
        r.migrate(0, 1 - home).unwrap();
    }
    let toks = front_turn(addr, 0, vec![9, 9], 3);
    assert_eq!(toks.len(), 3);

    let (status, body) = http_get(front.http_addr(), "/metrics");
    assert_eq!(status, 200, "scrape failed: {body}");
    // merged latency histograms: 9 turns total, every sample present in
    // the cluster-wide _count regardless of which shard served it
    assert!(body.contains("# TYPE lh_ttft_seconds histogram"), "{body}");
    assert!(body.contains("lh_ttft_seconds_count 9\n"), "{body}");
    assert!(body.contains("lh_ttft_seconds_bucket{le=\"+Inf\"} 9\n"), "{body}");
    assert!(body.contains("# TYPE lh_tpot_seconds histogram"), "{body}");
    assert!(body.contains("lh_tpot_seconds_count 9\n"), "{body}");
    assert!(body.contains("lh_e2e_seconds_count 9\n"), "{body}");
    // shard-side counters sum across the cluster
    assert!(body.contains("lh_requests_done_total 9\n"), "{body}");
    // both breakers closed, reported per shard
    assert!(body.contains("lh_breaker_state{shard=\"0\"} 0\n"), "{body}");
    assert!(body.contains("lh_breaker_state{shard=\"1\"} 0\n"), "{body}");
    // the migration shows up in the router-side counters
    assert!(body.contains("lh_migration_attempts_total 1\n"), "{body}");
    assert!(body.contains("lh_migration_commits_total 1\n"), "{body}");
    assert!(body.contains("lh_migration_aborts_total 0\n"), "{body}");
    assert!(body.contains("lh_scrape_errors_total 0\n"), "{body}");
    // front-door instrumentation rode along in the same snapshot
    assert!(body.contains("lh_front_requests_total 9\n"), "{body}");
    assert!(body.contains("lh_front_in_flight 0\n"), "{body}");

    // the dashboard and the trace ring serve the same cluster
    let (status, admin) = http_get(front.http_addr(), "/admin");
    assert_eq!(status, 200);
    assert!(admin.contains("migrations: 1 attempted, 1 committed"), "{admin}");
    let (status, traces) = http_get(front.http_addr(), "/traces");
    assert_eq!(status, 200);
    assert_eq!(traces.lines().count(), 9, "one trace per front turn: {traces}");
    assert!(traces.contains("\"ok\":true"), "{traces}");

    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}

/// A scrape issued while a streamed turn is in flight (held open by an
/// injected token-stream delay) must wait the turn out and then succeed:
/// the stream is never cut, and the scrape observes the completed
/// request.
#[test]
fn mid_generation_scrape_waits_out_the_stream_and_succeeds() {
    let (shards, front, faults) = launch(2);
    // hold the token relay open mid-stream so the scrape demonstrably
    // arrives while the turn is still streaming
    faults.add_rule(Rule {
        shard: None,
        point: Point::TokenStream { after: 2 },
        action: FaultAction::Delay(Duration::from_millis(300)),
        times: 1,
    });
    let (tx, rx) = mpsc::channel();
    let addr = front.addr();
    let client = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        match wire::read_frame(&mut s).unwrap() {
            Frame::Hello { .. } => {}
            other => panic!("expected Hello greeting, got {other:?}"),
        }
        wire::write_frame(
            &mut s,
            &Frame::SubmitInSession {
                session: 7,
                strict: false,
                max_new: 5,
                deadline_ms: 0,
                trace: 0,
                profile: false,
                delta: vec![3, 1, 4],
            },
        )
        .unwrap();
        let mut toks = Vec::new();
        loop {
            match wire::read_frame(&mut s).unwrap() {
                Frame::Token { token } => {
                    toks.push(token);
                    let _ = tx.send(());
                }
                Frame::Done { .. } => return toks,
                other => panic!("expected Token/Done, got {other:?}"),
            }
        }
    });
    // first streamed token seen → the turn is in flight; scrape now.
    // The /metrics handler blocks on the router lock the relay holds, so
    // by the time the response arrives the turn must be complete.
    rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let (status, body) = http_get(front.http_addr(), "/metrics");
    assert_eq!(status, 200, "mid-generation scrape failed: {body}");
    assert!(
        body.contains("lh_requests_done_total 1\n"),
        "the scrape waits out the in-flight turn, so it sees it done: {body}"
    );
    let toks = client.join().unwrap();
    assert_eq!(toks.len(), 5, "the scrape must never cut a live stream");
    assert_eq!(faults.rules_pending(), 0, "the staged mid-stream delay never fired");
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}

/// Malformed, oversized and non-GET requests each get their typed HTTP
/// error over a real socket — and the endpoint keeps serving afterward.
#[test]
fn http_error_paths_are_typed_and_leave_the_endpoint_alive() {
    let (shards, front, _faults) = launch(2);
    let http = front.http_addr();
    let (status, _) = http_get_raw(http, b"POST /metrics HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status, 405, "non-GET must be refused as method-not-allowed");
    let (status, _) = http_get_raw(http, b"\x00\xff garbage\r\n\r\n");
    assert_eq!(status, 400, "malformed head must be a bad request");
    let (status, _) = http_get(http, "/nope");
    assert_eq!(status, 404);
    let mut huge = b"GET /metrics HTTP/1.1\r\n".to_vec();
    huge.extend(vec![b'a'; 64 * 1024]);
    let (status, _) = http_get_raw(http, &huge);
    assert_eq!(status, 431, "an unbounded header must be refused, not buffered");
    // none of that killed the listener: a well-formed scrape still works
    let (status, body) = http_get(http, "/metrics");
    assert_eq!(status, 200);
    assert!(body.contains("lh_requests_done_total 0\n"), "{body}");
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}
