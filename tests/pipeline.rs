//! Integration tests across module boundaries: distillery → SSM zoo →
//! engines → coordinator, without PJRT (those paths are covered by the
//! runtime unit tests against real artifacts).

use laughing_hyena::config::{ModelConfig, RawConfig, ServeConfig};
use laughing_hyena::coordinator::server::{spawn, SlotEngine};
use laughing_hyena::data::filters::{model_filters, Family};
use laughing_hyena::distill::{DistillConfig, Distillery};
use laughing_hyena::dsp::conv::causal_conv_direct;
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::LmShape;
use laughing_hyena::ssm::TransferFunction;
use laughing_hyena::util::stats::rel_err;
use laughing_hyena::util::Prng;

#[test]
fn distill_then_deploy_all_realizations_agree() {
    // filter -> modal fit -> tf -> companion: all three realizations must
    // produce the same outputs on fresh inputs
    let f = &model_filters(Family::H3Iir, 1, 192, 3)[0];
    let distillery = Distillery {
        order: Some(6),
        fit: DistillConfig { iters: 2000, ..Default::default() },
        hankel_window: Some(48),
        ..Default::default()
    };
    let out = distillery.distill_filter(f);
    assert!(out.rel_err < 0.05, "distillation failed: {}", out.rel_err);

    let mut rng = Prng::new(9);
    let u = rng.normal_vec(300);
    let modal_y = out.ssm.filter(&u);
    let conv_y = causal_conv_direct(f, &u);
    assert!(rel_err(&modal_y, &conv_y) < 0.1, "{}", rel_err(&modal_y, &conv_y));

    // Companion cross-check: converting clustered near-unit-circle poles
    // to polynomial coefficients rounds them, and a rounded root
    // marginally outside the circle diverges — exactly the §3.2 fragility
    // that motivates the *modal* parametrization.  So the canonization
    // path is verified on the well-conditioned dominant part of the
    // system (modal truncation to the true mode count), while the full
    // distilled system is checked for the instability being *detectable*
    // via the companion poles.
    let dominant = laughing_hyena::distill::modal_trunc::modal_truncate(&out.ssm, 4);
    let comp = TransferFunction::from_modal_real(&dominant).to_companion();
    let horizon = 96;
    let comp_y = comp.filter(&u[..horizon]);
    let dom_y: Vec<f64> = dominant.filter(&u[..horizon]);
    assert!(
        rel_err(&comp_y, &dom_y) < 1e-6,
        "companion drift {}",
        rel_err(&comp_y, &dom_y)
    );
    // full system: either the conversion is stable or its instability is
    // visible in the companion spectral radius (never silent corruption)
    let full_comp = TransferFunction::from_modal_real(&out.ssm).to_companion();
    let rho = full_comp.poles().iter().map(|p| p.abs()).fold(0.0, f64::max);
    let full_y = full_comp.filter(&u[..horizon]);
    let drift = rel_err(&full_y, &modal_y[..horizon]);
    assert!(
        drift < 1e-3 || rho > 0.999,
        "silent companion corruption: drift {drift}, rho {rho}"
    );
}

#[test]
fn distilled_engine_serves_through_coordinator() {
    // distill synthetic filters, install them in the recurrent engine, and
    // push requests through the full coordinator
    let shape = LmShape::bench("nano").unwrap();
    let filters = model_filters(Family::Hyena, shape.heads, 128, 5);
    let distillery = Distillery {
        order: Some(shape.d_state),
        fit: DistillConfig { iters: 800, ..Default::default() },
        hankel_window: Some(48),
        ..Default::default()
    };
    let systems: Vec<_> = filters.iter().map(|f| distillery.distill_filter(f).ssm).collect();
    let padded: Vec<_> = systems
        .iter()
        .map(|s| laughing_hyena::experiments::common::pad_modal(s, shape.d_state))
        .collect();
    let n_layer = shape.n_layer;
    let handle = spawn(
        move || {
            let mut eng = RecurrentEngine::new(&shape, 2, 7);
            for l in 0..n_layer {
                eng.set_layer_modal(l, &padded);
            }
            Box::new(eng) as Box<dyn SlotEngine>
        },
        ServeConfig {
            max_batch: 2,
            linger_ms: 1,
            max_new_tokens: 8,
            mem_budget: 1 << 30,
            ..ServeConfig::default()
        },
    );
    let rxs: Vec<_> =
        (0..4).map(|i| handle.submit(vec![i + 1, 2, 3], 6).expect("alive")).collect();
    for rx in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(r.tokens.len(), 6);
    }
    handle.shutdown();
}

#[test]
fn config_round_trip_drives_launcher_types() {
    let raw = RawConfig::parse(
        "[model]\npreset = \"tiny\"\nkind = \"multihyena\"\n[serve]\nmax_batch = 3\n",
    )
    .unwrap();
    let mc = ModelConfig::from_raw(&raw);
    assert_eq!(mc.vocab, 64);
    assert_eq!(mc.n_filters(), 4);
    let sc = ServeConfig::from_raw(&raw);
    assert_eq!(sc.max_batch, 3);
}

#[test]
fn hankel_order_predicts_distillation_quality() {
    // the §3.3 claim end-to-end: distilling BELOW the Hankel knee is bad,
    // at/above the knee is good
    let f = &model_filters(Family::Hyena, 1, 256, 11)[0];
    let sv = laughing_hyena::hankel::hankel_singular_values(&f[1..], Some(64));
    let knee = laughing_hyena::hankel::suggest_order(&sv, 1e-3);
    assert!(knee >= 4, "synthetic hyena filter should not be trivial (knee {knee})");
    let fit = |order: usize| {
        let d = Distillery {
            order: Some(order),
            fit: DistillConfig { iters: 1500, ..Default::default() },
            hankel_window: Some(64),
            ..Default::default()
        };
        d.distill_filter(f).rel_err
    };
    let below = fit(2.max(knee / 4));
    let at = fit(knee + 2);
    assert!(
        at < below * 0.5,
        "knee {knee}: err(below)={below:.3e} err(at)={at:.3e}"
    );
}
