//! Chaos suite for the serve layer: deterministic fault injection at
//! named protocol points (no sleeps, no real crashes, no racing).
//!
//! Every scenario compares the surviving conversation against an
//! uninterrupted single-coordinator baseline, so "survived" always means
//! *bit-identical tokens*, and every staged fault is asserted to have
//! actually fired (`rules_pending() == 0`) — a fault that never fires is
//! a test of nothing.
//!
//! The invariants under fire:
//!
//! * a shard killed mid-conversation → the session is resurrected from
//!   the router's transcript mirror on a survivor, token-identically;
//! * a token stream severed mid-turn → the router reconciles against the
//!   shard's transcript and the client still sees every token exactly
//!   once, with no replayed turn;
//! * a migration severed at *each* commit/abort protocol window → the
//!   session ends up live in exactly one coordinator (never zero, never
//!   two) and keeps producing the baseline's tokens.

use std::sync::Arc;
use std::time::Duration;

use laughing_hyena::config::ServeConfig;
use laughing_hyena::coordinator::server::spawn;
use laughing_hyena::coordinator::{CoordinatorHandle, SlotEngine};
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::LmShape;
use laughing_hyena::serve::{
    BreakerConfig, Cluster, FaultAction, FaultPlan, FrameKind, Point, Rule,
};

/// Every shard and the reference coordinator share this seed, so all
/// engines carry identical weights — the precondition for bit-identical
/// recovery anywhere in the cluster.
const SEED: u64 = 11;

fn cfg() -> ServeConfig {
    ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
}

fn shape() -> LmShape {
    LmShape::bench("nano").unwrap()
}

/// The uninterrupted baseline: one coordinator, never faulted.
fn reference() -> CoordinatorHandle {
    let shape = shape();
    spawn(
        move || Box::new(RecurrentEngine::new(&shape, 2, SEED)) as Box<dyn SlotEngine>,
        cfg(),
    )
}

fn turn(h: &CoordinatorHandle, sid: u64, delta: Vec<i32>, n: usize) -> Vec<i32> {
    h.submit_in_session(sid, delta, n)
        .unwrap()
        .recv_timeout(Duration::from_secs(120))
        .unwrap()
        .tokens
}

/// An `n`-shard cluster with a shared fault plan threaded into the router.
fn chaos_cluster(n: usize) -> (Cluster, Arc<FaultPlan>) {
    let faults = Arc::new(FaultPlan::new());
    let cluster = Cluster::launch_native_with(
        n,
        &shape(),
        2,
        SEED,
        &cfg(),
        BreakerConfig::default(),
        Some(faults.clone()),
    )
    .unwrap();
    (cluster, faults)
}

/// Tentpole: kill a session's home shard mid-conversation.  The next
/// (streamed) turn must be answered anyway — resurrected from the
/// router's transcript mirror on the surviving shard — and be
/// token-identical to the uninterrupted baseline, with every token
/// delivered to the streaming callback exactly once.
#[test]
fn killed_shard_mid_conversation_resurrects_token_identically() {
    let (mut cluster, faults) = chaos_cluster(2);
    let h_ref = reference();
    let sid = 0xDEAD5EED;
    let (d1, d2, d3, d4) = (vec![3, 1, 4], vec![1, 5, 9], vec![2, 6], vec![5, 3]);

    let g1 = cluster.router.submit_in_session(sid, d1.clone(), 4).unwrap();
    let g2 = cluster.router.submit_in_session(sid, d2.clone(), 3).unwrap();
    assert_eq!(g1, turn(&h_ref, sid, d1, 4));
    assert_eq!(g2, turn(&h_ref, sid, d2, 3));

    // the home shard "crashes": every connect to it is refused from here on
    let home = cluster.router.shard_of(sid).unwrap();
    faults.kill(cluster.shards[home].addr());

    let mut streamed = Vec::new();
    let g3 = cluster
        .router
        .submit_in_session_streaming(sid, d3.clone(), 5, |t| streamed.push(t))
        .unwrap();
    let r3 = turn(&h_ref, sid, d3, 5);
    assert_eq!(g3, r3, "resurrected turn diverged from the uninterrupted run");
    assert_eq!(streamed, r3, "stream must carry every token exactly once");

    // the session now lives on a survivor, and that shard truly holds it
    let new_home = cluster.router.shard_of(sid).unwrap();
    assert_ne!(new_home, home, "the session cannot stay on the killed shard");
    assert!(
        cluster.shards[new_home].handle.session_known(sid).unwrap(),
        "the surviving shard's coordinator must hold the resurrected session"
    );

    // and the conversation just keeps going on the new home
    let g4 = cluster.router.submit_in_session(sid, d4.clone(), 3).unwrap();
    assert_eq!(g4, turn(&h_ref, sid, d4, 3), "post-resurrection turn diverged");
    assert_eq!(cluster.router.shard_of(sid), Some(new_home));

    h_ref.shutdown();
    cluster.shutdown();
}

/// A token stream severed mid-turn while the shard stays up: the
/// coordinator finishes the turn even though the relay died, so the
/// router must *reconcile* (fetch the transcript, deliver the unseen
/// suffix) rather than replay — and the client sees each token once.
#[test]
fn severed_token_stream_reconciles_without_replaying_the_turn() {
    let (mut cluster, faults) = chaos_cluster(2);
    let h_ref = reference();
    let sid = 0x5EED;
    let (d1, d2) = (vec![4, 2, 4], vec![8, 1]);

    let g1 = cluster.router.submit_in_session(sid, d1.clone(), 3).unwrap();
    assert_eq!(g1, turn(&h_ref, sid, d1, 3));
    let home = cluster.router.shard_of(sid).unwrap();

    // sever the relay connection after exactly 2 streamed tokens
    faults.add_rule(Rule::once(Point::TokenStream { after: 2 }, FaultAction::SeverAfter));

    let mut streamed = Vec::new();
    let g2 = cluster
        .router
        .submit_in_session_streaming(sid, d2.clone(), 6, |t| streamed.push(t))
        .unwrap();
    let r2 = turn(&h_ref, sid, d2, 6);
    assert_eq!(g2, r2, "reconciled turn diverged from the uninterrupted run");
    assert_eq!(
        streamed, r2,
        "the client must see every token exactly once across the sever"
    );
    assert_eq!(faults.rules_pending(), 0, "the staged sever never fired");
    assert_eq!(
        cluster.router.shard_of(sid),
        Some(home),
        "reconcile must keep the session where it is"
    );

    // reconcile accepted the finished turn: two generation requests total
    // (turn 1 + the severed-but-completed turn), no replayed third
    let health = cluster.router.health().unwrap();
    let done: u64 = health.iter().map(|h| h.requests_done).sum();
    assert_eq!(done, 2, "a replay would have run a third generation");
    assert_eq!(health.iter().map(|h| h.session_misses).sum::<u64>(), 0);

    h_ref.shutdown();
    cluster.shutdown();
}

/// One protocol window of the 2PC migration under injected failure.
struct SeverCase {
    name: &'static str,
    rules: Vec<Rule>,
    /// Expected `migrate` outcome (`Ok` when the probe proves the import
    /// landed, `Err` when the migration was aborted back to the source).
    migrate_ok: bool,
    /// Where the session must be live afterwards.
    lands_on_target: bool,
    /// Stale (inactive, coordinator-invisible) entries left in the
    /// source's export stash — only the commit-lost-forever window leaves
    /// one, and it must never be a live duplicate.
    stale_stash: usize,
}

/// Satellite: sever a live migration at *each* point of the export /
/// import / commit / abort protocol.  After every single one: the session
/// is live in exactly one coordinator (asserted against both shards'
/// coordinators directly, not just the router's bookkeeping), the export
/// stash settles as specified, and the conversation's next turn is
/// bit-identical to the uninterrupted baseline.
#[test]
fn migration_severed_at_every_protocol_point_keeps_exactly_one_live_copy() {
    let drop_at = |p: Point| Rule::once(p, FaultAction::DropFrame);
    let cases = vec![
        SeverCase {
            name: "export request dropped — source never sees it",
            rules: vec![drop_at(Point::Send(FrameKind::Export))],
            migrate_ok: false,
            lands_on_target: false,
            stale_stash: 0,
        },
        SeverCase {
            name: "export reply lost — abort re-imports the stash",
            rules: vec![drop_at(Point::RecvReplyTo(FrameKind::Export))],
            migrate_ok: false,
            lands_on_target: false,
            stale_stash: 0,
        },
        SeverCase {
            name: "import request dropped — probe finds nothing, abort",
            rules: vec![drop_at(Point::Send(FrameKind::Import))],
            migrate_ok: false,
            lands_on_target: false,
            stale_stash: 0,
        },
        SeverCase {
            name: "import Ok lost — probe proves it landed, commit",
            rules: vec![drop_at(Point::RecvReplyTo(FrameKind::Import))],
            migrate_ok: true,
            lands_on_target: true,
            stale_stash: 0,
        },
        SeverCase {
            name: "commit dropped once — settlement retry clears the stash",
            rules: vec![drop_at(Point::Send(FrameKind::ExportCommit))],
            migrate_ok: true,
            lands_on_target: true,
            stale_stash: 0,
        },
        SeverCase {
            name: "commit lost for good — stale stash, never a duplicate",
            rules: vec![Rule {
                shard: None,
                point: Point::Send(FrameKind::ExportCommit),
                action: FaultAction::DropFrame,
                times: 2,
            }],
            migrate_ok: true,
            lands_on_target: true,
            stale_stash: 1,
        },
        SeverCase {
            name: "abort dropped once — settlement retry restores the source",
            rules: vec![
                drop_at(Point::RecvReplyTo(FrameKind::Export)),
                drop_at(Point::Send(FrameKind::ExportAbort)),
            ],
            migrate_ok: false,
            lands_on_target: false,
            stale_stash: 0,
        },
    ];

    for case in cases {
        let name = case.name;
        let (mut cluster, faults) = chaos_cluster(2);
        let h_ref = reference();
        let sid = 0xC0FFEE;
        let (d1, d2, d3) = (vec![3, 1, 4, 1], vec![5, 9, 2], vec![6, 5]);

        let g1 = cluster.router.submit_in_session(sid, d1.clone(), 3).unwrap();
        let g2 = cluster.router.submit_in_session(sid, d2.clone(), 4).unwrap();
        assert_eq!(g1, turn(&h_ref, sid, d1, 3), "turn 1 diverged before the fault ({name})");
        assert_eq!(g2, turn(&h_ref, sid, d2, 4), "turn 2 diverged before the fault ({name})");

        let home = cluster.router.shard_of(sid).unwrap();
        let target = 1 - home;
        for rule in &case.rules {
            faults.add_rule(*rule);
        }

        let res = cluster.router.migrate(sid, target);
        assert_eq!(
            res.is_ok(),
            case.migrate_ok,
            "unexpected migrate outcome ({name}): {res:?}"
        );
        assert_eq!(faults.rules_pending(), 0, "a staged fault never fired ({name})");
        assert!(!faults.hits().is_empty(), "no fault hit was recorded ({name})");

        // exactly one live copy — asked of the coordinators themselves
        let on_home = cluster.shards[home].handle.session_known(sid).unwrap();
        let on_target = cluster.shards[target].handle.session_known(sid).unwrap();
        assert!(
            on_home ^ on_target,
            "session must be live in exactly one coordinator ({name}): \
             home={on_home} target={on_target}"
        );
        assert_eq!(on_target, case.lands_on_target, "session on the wrong side ({name})");
        let owner = if case.lands_on_target { target } else { home };
        assert_eq!(
            cluster.router.shard_of(sid),
            Some(owner),
            "router residency out of sync with the coordinators ({name})"
        );
        assert_eq!(
            cluster.shards[home].pending_exports(),
            case.stale_stash,
            "unexpected export-stash residue on the source ({name})"
        );
        assert_eq!(cluster.shards[target].pending_exports(), 0, "target stash dirty ({name})");

        // whichever side it landed on, the conversation is intact
        let g3 = cluster.router.submit_in_session(sid, d3.clone(), 5).unwrap();
        assert_eq!(g3, turn(&h_ref, sid, d3, 5), "turn 3 diverged after the fault ({name})");
        let health = cluster.router.health().unwrap();
        assert_eq!(
            health.iter().map(|h| h.session_misses).sum::<u64>(),
            0,
            "a recovery fell back to re-prefill instead of stored state ({name})"
        );

        h_ref.shutdown();
        cluster.shutdown();
    }
}
