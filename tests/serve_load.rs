//! Chaos-under-load acceptance harness: the deterministic loadgen
//! workload driven over real loopback wire connections against a sharded
//! cluster while shards are killed, revived and drained underneath it.
//!
//! The overload-hardening invariants under fire:
//!
//! * every **accepted** turn is delivered exactly once and bit-identical
//!   to an uninterrupted single-coordinator baseline replaying the same
//!   accepted-turn sequence — across a mid-run shard kill, its revival,
//!   and a bulk drain of a third shard;
//! * every **shed** turn is a *typed* refusal
//!   ([`ErrCode::Overloaded`] / [`ErrCode::DeadlineExceeded`]), never a
//!   hung or severed connection, and a shed turn is never applied to
//!   session state;
//! * sessions TTL-evicted to **zero shard RAM** (state, spill index and
//!   transcript all gone — the census is compared against the all-zero
//!   [`SessionCensus`]) resume losslessly via transcript re-prefill from
//!   the router's mirror, bit-identical to a never-evicted baseline;
//! * after the storm the **session census reconciles**: every session is
//!   live in exactly one coordinator, no export stash holds residue, and
//!   nothing is left in flight.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use laughing_hyena::config::ServeConfig;
use laughing_hyena::coordinator::server::spawn;
use laughing_hyena::coordinator::{CoordinatorHandle, SessionCensus, SlotEngine};
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::LmShape;
use laughing_hyena::loadgen::{self, LoadConfig};
use laughing_hyena::obs::registry::{MetricValue, Snapshot};
use laughing_hyena::serve::wire;
use laughing_hyena::serve::{
    BreakerConfig, Cluster, ErrCode, FaultAction, FaultPlan, Frame, FrontConfig, FrontServer,
    Point, Rule, ShardServer,
};

/// Every shard and the reference coordinator share this seed, so all
/// engines carry identical weights — the precondition for bit-identical
/// recovery anywhere in the cluster.
const SEED: u64 = 11;

/// Tokens requested per load turn.
const MAX_NEW: usize = 3;

/// Deadline budget on patient load turns: generous, so under this test's
/// load nothing *patient* is ever shed and every refusal is deliberate.
const PATIENT_MS: u32 = 120_000;

fn cfg() -> ServeConfig {
    ServeConfig { max_batch: 4, linger_ms: 1, ..ServeConfig::default() }
}

fn shape() -> LmShape {
    LmShape::bench("nano").unwrap()
}

/// The uninterrupted baseline: one coordinator, never faulted, no TTL.
fn reference(serve_cfg: ServeConfig) -> CoordinatorHandle {
    let shape = shape();
    spawn(
        move || Box::new(RecurrentEngine::new(&shape, 4, SEED)) as Box<dyn SlotEngine>,
        serve_cfg,
    )
}

fn ref_turn(h: &CoordinatorHandle, sid: u64, delta: Vec<i32>, n: usize) -> Vec<i32> {
    h.submit_in_session(sid, delta, n)
        .unwrap()
        .recv_timeout(Duration::from_secs(120))
        .unwrap()
        .tokens
}

/// An `n`-shard cluster + front door with a shared fault plan and zero
/// breaker cooldown (so a revived shard can rejoin within the test).
fn launch(
    n: usize,
    serve_cfg: &ServeConfig,
    max_inflight: usize,
) -> (Vec<ShardServer>, FrontServer, Arc<FaultPlan>) {
    let faults = Arc::new(FaultPlan::new());
    let cluster = Cluster::launch_native_with(
        n,
        &shape(),
        4,
        SEED,
        serve_cfg,
        BreakerConfig { cooldown: Duration::ZERO, ..BreakerConfig::default() },
        Some(faults.clone()),
    )
    .unwrap();
    let (shards, router) = cluster.into_parts();
    let front = FrontServer::spawn(
        router,
        FrontConfig { max_inflight, probe_interval: None, ..FrontConfig::default() },
    )
    .unwrap();
    (shards, front, faults)
}

/// One wire-level turn through the front door.  `Ok(tokens)` for a
/// completed generation, `Err(code)` for a typed refusal frame; anything
/// else (transport failure, protocol surprise) panics the worker — under
/// this harness a non-typed failure is a bug, not load.
fn wire_turn(
    addr: SocketAddr,
    sid: u64,
    delta: &[i32],
    max_new: u32,
    deadline_ms: u32,
) -> Result<Vec<i32>, ErrCode> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    match wire::read_frame(&mut s).unwrap() {
        Frame::Hello { .. } => {}
        other => panic!("expected Hello greeting, got {other:?}"),
    }
    wire::write_frame(
        &mut s,
        &Frame::SubmitInSession {
            session: sid,
            strict: false,
            max_new,
            deadline_ms,
            trace: 0,
            profile: false,
            delta: delta.to_vec(),
        },
    )
    .unwrap();
    let mut toks = Vec::new();
    loop {
        match wire::read_frame(&mut s).unwrap() {
            Frame::Token { token } => toks.push(token),
            Frame::Done { .. } => return Ok(toks),
            Frame::Error { code, .. } => return Err(code),
            other => panic!("expected Token/Done/Error, got {other:?}"),
        }
    }
}

fn counter(snap: &Snapshot, name: &str) -> u64 {
    match snap.entries.get(name) {
        Some(MetricValue::Counter(v)) | Some(MetricValue::Gauge(v)) => *v,
        _ => 0,
    }
}

/// Poll until `pred` holds or the timeout elapses (then panic with `what`).
fn wait_until(what: &str, timeout: Duration, mut pred: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !pred() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(5));
    }
}

/// The tentpole: 200 concurrent sessions drive the deterministic loadgen
/// workload over the wire while the test kills a shard mid-run, revives
/// it, then bulk-drains another shard — and afterwards replays every
/// *accepted* turn on an uninterrupted baseline coordinator, demanding
/// bit-identical tokens turn by turn.  Deliberately shed work (tiny
/// deadline budgets submitted against a verifiably full admission gate)
/// must come back as typed refusals and leave no trace in any session.
/// Finally the census reconciles: each session live in exactly one
/// coordinator, empty export stashes, nothing in flight.
#[test]
fn chaos_under_load_delivers_accepted_turns_exactly_once_bit_identically() {
    let n_shards = 3;
    let (shards, front, faults) = launch(n_shards, &cfg(), 4);
    let addr = front.addr();
    let router = front.router();

    // the deterministic workload: 200 sessions, ~2 turns each
    let load_cfg = LoadConfig {
        sessions: 200,
        turns: 2,
        rate_hz: 0.0,
        think_ms: 1,
        prompt_len: 4,
        max_new: MAX_NEW,
        deadline_ms: PATIENT_MS,
        seed: 42,
    };
    let plans = loadgen::plan(&load_cfg);
    let total_turns: usize = plans.iter().map(|p| p.turns.len()).sum();
    assert!(total_turns >= 200, "workload too small to call this load");

    let done = Arc::new(AtomicU64::new(0));
    let workers: Vec<_> = plans
        .into_iter()
        .map(|sp| {
            let done = Arc::clone(&done);
            thread::spawn(move || {
                let mut log: Vec<(Vec<i32>, Option<Vec<i32>>)> = Vec::new();
                for t in &sp.turns {
                    if t.think > Duration::ZERO {
                        thread::sleep(t.think);
                    }
                    match wire_turn(addr, sp.sid, &t.delta, MAX_NEW as u32, PATIENT_MS) {
                        Ok(toks) => {
                            assert_eq!(toks.len(), MAX_NEW, "short generation accepted");
                            log.push((t.delta.clone(), Some(toks)));
                        }
                        Err(code) => {
                            assert!(
                                matches!(
                                    code,
                                    ErrCode::Overloaded | ErrCode::DeadlineExceeded
                                ),
                                "shed work must be typed Overloaded/DeadlineExceeded, \
                                 got {code:?}"
                            );
                            log.push((t.delta.clone(), None));
                        }
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                }
                (sp.sid, log)
            })
        })
        .collect();

    // chaos choreography, keyed to load progress: kill shard 0 a third of
    // the way in, revive it two thirds in — turns homed there in between
    // are resurrected from the router's transcript mirror on survivors
    let third = (total_turns / 3) as u64;
    wait_until("one third of the load", Duration::from_secs(180), || {
        done.load(Ordering::SeqCst) >= third
    });
    faults.kill(shards[0].addr());
    wait_until("two thirds of the load", Duration::from_secs(180), || {
        done.load(Ordering::SeqCst) >= 2 * third
    });
    faults.revive(shards[0].addr());
    router.lock().unwrap().probe_all();

    let mut logs: HashMap<u64, Vec<(Vec<i32>, Option<Vec<i32>>)>> = HashMap::new();
    for w in workers {
        let (sid, log) = w.join().expect("load worker panicked");
        logs.insert(sid, log);
    }
    let shed_under_load: u64 =
        logs.values().flatten().filter(|(_, toks)| toks.is_none()).count() as u64;

    // drain churn under the same cluster: bulk-move everything off shard
    // 1, then keep conversing on a sample of the moved sessions
    let moved = router.lock().unwrap().drain(1).unwrap();
    assert!(!moved.is_empty(), "a 200-session load left shard 1 empty?");
    for &sid in moved.iter().take(8) {
        let home = router.lock().unwrap().shard_of(sid);
        assert_ne!(home, Some(1), "session {sid:#x} still routed at the drained shard");
        let delta = vec![7, 3];
        let toks = wire_turn(addr, sid, &delta, MAX_NEW as u32, PATIENT_MS)
            .expect("post-drain turn refused");
        logs.get_mut(&sid).unwrap().push((delta, Some(toks)));
    }

    // deliberate shed phase: four streams held open mid-token (gate
    // verifiably full) while impatient turns with a 1 ms budget queue
    // behind them — every one must come back a typed refusal
    faults.add_rule(Rule {
        shard: None,
        point: Point::TokenStream { after: 1 },
        action: FaultAction::Delay(Duration::from_millis(1500)),
        times: 4,
    });
    let blockers: Vec<_> = (0..4u64)
        .map(|i| {
            thread::spawn(move || {
                wire_turn(addr, 0x9000 + i, &[1 + i as i32, 2], MAX_NEW as u32, PATIENT_MS)
                    .expect("blocker turn refused")
            })
        })
        .collect();
    wait_until("the admission gate to fill", Duration::from_secs(60), || {
        front.in_flight() == 4
    });
    let impatient = 6u64;
    for i in 0..impatient {
        assert_eq!(front.in_flight(), 4, "a blocker finished early; gate not provably full");
        let got = wire_turn(addr, 0xA000 + i, &[9, 9, 9], MAX_NEW as u32, 1);
        assert_eq!(
            got,
            Err(ErrCode::Overloaded),
            "an impatient turn against a full gate must shed typed"
        );
    }
    for b in blockers {
        let toks = b.join().expect("blocker panicked");
        assert_eq!(toks.len(), MAX_NEW);
    }
    assert_eq!(faults.rules_pending(), 0, "a staged stream delay never fired");
    // shed turns were never applied: the impatient sessions do not exist
    for shard in &shards {
        for i in 0..impatient {
            assert!(
                !shard.handle.session_known(0xA000 + i).unwrap(),
                "a typed-shed turn leaked session state onto a shard"
            );
        }
    }
    let front_snap = front.front_metrics();
    assert_eq!(
        counter(&front_snap, "lh_front_shed_deadline_total"),
        shed_under_load + impatient,
        "every shed must be counted exactly once"
    );

    // exactly-once, bit-identical: replay each session's accepted turns
    // (and only those — shed turns were never applied) on the baseline
    let h_ref = reference(cfg());
    let mut accepted = 0u64;
    let mut sids: Vec<u64> = logs.keys().copied().collect();
    sids.sort_unstable();
    for sid in sids {
        for (turn_no, (delta, toks)) in logs[&sid].iter().enumerate() {
            if let Some(toks) = toks {
                let expect = ref_turn(&h_ref, sid, delta.clone(), MAX_NEW);
                assert_eq!(
                    toks, &expect,
                    "session {sid:#x} accepted turn {turn_no} diverged from the \
                     uninterrupted baseline"
                );
                accepted += 1;
            }
        }
    }
    assert_eq!(
        accepted + shed_under_load,
        total_turns as u64 + 8,
        "accepted + shed must account for every load turn plus the 8 post-drain turns \
         (the 4 blocker turns live on 0x9000+ sessions outside the logs)"
    );

    // the kill left stale copies on shard 0 for sessions resurrected
    // elsewhere; retire them, then demand a fully reconciled census
    {
        let r = router.lock().unwrap();
        for sid in logs.keys().copied() {
            if r.shard_of(sid) != Some(0) && shards[0].handle.session_known(sid).unwrap() {
                shards[0].handle.end_session(sid).unwrap();
            }
        }
    }
    for sid in logs.keys().copied() {
        wait_until("stale copies to retire", Duration::from_secs(30), || {
            let live: usize = shards
                .iter()
                .map(|s| s.handle.session_known(sid).unwrap() as usize)
                .sum();
            live == 1
        });
        let home = router.lock().unwrap().shard_of(sid).expect("session unplaced");
        assert!(
            shards[home].handle.session_known(sid).unwrap(),
            "session {sid:#x} not live on its routed home {home}"
        );
    }
    let snap = router.lock().unwrap().cluster_metrics();
    assert!(
        counter(&snap, "lh_resurrections_total") >= 1,
        "the kill window never exercised transcript-mirror resurrection"
    );
    for (i, shard) in shards.iter().enumerate() {
        let census = shard.handle.session_census().unwrap();
        assert_eq!(census.in_flight, 0, "shard {i} still has turns in flight");
        assert_eq!(
            census.transcripts,
            shard.handle.session_list().unwrap().len() as u64,
            "shard {i} census out of sync with its own session list"
        );
        assert_eq!(shard.pending_exports(), 0, "shard {i} export stash holds residue");
    }

    h_ref.shutdown();
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}

/// TTL under load: sessions served once, idled past the TTL so the sweep
/// frees them to *zero shard RAM* (the census equals the all-zero
/// [`SessionCensus`] — state, spill index and transcript all gone), then
/// resumed through the front door.  The resumed turns must be
/// bit-identical to a baseline that never evicted anything: the router's
/// transcript mirror re-prefills losslessly.
#[test]
fn ttl_evicted_sessions_resume_bit_identically_from_zero_shard_ram() {
    let serve_cfg = ServeConfig { session_ttl_ms: 150, ..cfg() };
    let (shards, front, _faults) = launch(2, &serve_cfg, 32);
    let addr = front.addr();
    let n_sessions = 24u64;

    let h_ref = reference(cfg());
    let delta1 = |sid: u64| vec![2 + (sid % 9) as i32; 5];
    let delta2 = |sid: u64| vec![1 + (sid % 6) as i32, 8];

    let mut first: Vec<Vec<i32>> = Vec::new();
    for sid in 0..n_sessions {
        let toks = wire_turn(addr, sid, &delta1(sid), MAX_NEW as u32, PATIENT_MS).unwrap();
        assert_eq!(toks, ref_turn(&h_ref, sid, delta1(sid), MAX_NEW), "turn 1 diverged");
        first.push(toks);
    }

    // idle past the TTL: the sweep must free every shard to zero RAM
    wait_until("the TTL sweep to zero both shards", Duration::from_secs(30), || {
        shards
            .iter()
            .all(|s| s.handle.session_census().unwrap() == SessionCensus::default())
    });
    let snap = front.router().lock().unwrap().cluster_metrics();
    assert!(
        counter(&snap, "lh_session_ttl_evictions_total") >= n_sessions,
        "every idle session must be TTL-evicted"
    );

    // resume every session: the shard holds nothing, so the router must
    // re-prefill from its transcript mirror — losslessly
    for sid in 0..n_sessions {
        let toks = wire_turn(addr, sid, &delta2(sid), MAX_NEW as u32, PATIENT_MS).unwrap();
        assert_eq!(
            toks,
            ref_turn(&h_ref, sid, delta2(sid), MAX_NEW),
            "session {sid:#x} post-TTL turn diverged: the re-prefill lost context"
        );
    }
    let snap = front.router().lock().unwrap().cluster_metrics();
    assert!(
        counter(&snap, "lh_resurrections_total") >= n_sessions,
        "post-TTL resumes must go through the transcript-mirror rebuild"
    );

    h_ref.shutdown();
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}

/// The loadgen module end-to-end: an open-loop run over a live cluster
/// completes every turn (generous budgets, no injected faults), its
/// client-side histograms account for exactly the completed turns, and
/// the workload size matches the deterministic plan.
#[test]
fn loadgen_open_loop_accounts_for_every_planned_turn() {
    let (shards, front, _faults) = launch(2, &cfg(), 16);
    let load_cfg = LoadConfig {
        sessions: 24,
        turns: 2,
        rate_hz: 200.0,
        think_ms: 1,
        prompt_len: 4,
        max_new: MAX_NEW,
        deadline_ms: PATIENT_MS,
        seed: 5,
    };
    let planned: usize = loadgen::plan(&load_cfg).iter().map(|p| p.turns.len()).sum();
    let report = loadgen::run(front.addr(), &load_cfg);

    assert_eq!(report.turns_submitted(), planned as u64);
    assert_eq!(report.turns_ok, planned as u64, "nothing should shed under this load");
    assert_eq!(report.transport_errors, 0);
    assert_eq!(report.tokens, (planned * MAX_NEW) as u64);
    assert_eq!(report.ttft.count(), planned as u64);
    assert_eq!(report.e2e.count(), planned as u64);
    assert!(report.e2e.mean() > 0.0, "completed turns must have recorded latencies");

    // the bench document renders the same accounting
    let doc = loadgen::bench_doc(
        &load_cfg,
        &report,
        &front.router().lock().unwrap().cluster_metrics(),
        &front.front_metrics(),
    )
    .to_string_pretty();
    assert!(doc.contains(&format!("\"turns_ok\": {planned}")), "{doc}");
    assert!(doc.contains("\"mode\": \"open\""), "{doc}");

    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}
