//! Loopback integration test of the serve layer: a router over 2-3
//! in-process shard servers on `127.0.0.1:0` sockets (kernel-assigned
//! ports, no network beyond loopback — sandbox-safe).
//!
//! The acceptance invariants:
//!
//! * interleaved sessions route with affinity (every second turn is a
//!   session-store *hit* on its home shard — a miss would mean a turn
//!   landed on the wrong shard);
//! * a **live-migrated** session's continuation is bit-identical to the
//!   same conversation served uninterrupted by a single coordinator;
//! * a version- or engine-tag-mismatched blob is rejected at the
//!   handshake and never restored;
//! * drain + add-shard + rebalance churn never changes any conversation's
//!   tokens;
//! * streamed turns keep session affinity (the per-token relay runs
//!   against the home shard) and the stream always equals the buffered
//!   return;
//! * an admin drain issued mid-token-stream defers until the stream
//!   completes — the session is never yanked out from under a live turn.

use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use laughing_hyena::config::ServeConfig;
use laughing_hyena::coordinator::server::spawn;
use laughing_hyena::coordinator::{CoordinatorHandle, SlotEngine};
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::LmShape;
use laughing_hyena::serve::wire;
use laughing_hyena::serve::{
    BreakerConfig, Cluster, ErrCode, FaultAction, FaultPlan, Frame, FrontConfig, FrontServer,
    Point, Router, Rule, ShardServer,
};
use laughing_hyena::session::{SessionState, FORMAT_VERSION};

/// Every shard and the reference coordinator share this seed, so all
/// engines carry identical weights — the precondition for bit-identical
/// cross-shard continuation.
const SEED: u64 = 11;

fn cfg() -> ServeConfig {
    ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
}

fn shape() -> LmShape {
    LmShape::bench("nano").unwrap()
}

/// The uninterrupted baseline: one coordinator, never migrated.
fn reference() -> CoordinatorHandle {
    let shape = shape();
    spawn(
        move || Box::new(RecurrentEngine::new(&shape, 2, SEED)) as Box<dyn SlotEngine>,
        cfg(),
    )
}

fn turn(h: &CoordinatorHandle, sid: u64, delta: Vec<i32>, n: usize) -> Vec<i32> {
    h.submit_in_session(sid, delta, n)
        .unwrap()
        .recv_timeout(Duration::from_secs(120))
        .unwrap()
        .tokens
}

/// The tentpole invariant (and satellite 3): a 3-turn conversation with
/// turns 1-2 answered on shard A, a live migration, and turn 3 answered
/// on shard B is token-identical to the uninterrupted single-coordinator
/// run — with interleaved noise sessions proving affinity along the way.
#[test]
fn migrated_session_continues_bit_identical_to_uninterrupted() {
    let mut cluster = Cluster::launch_native(2, &shape(), 2, SEED, &cfg()).unwrap();
    let h_ref = reference();
    let sid = 0xA11CE;
    let (d1, d2, d3) = (vec![3, 1, 4, 1, 5], vec![9, 2, 6], vec![5, 3, 5, 8]);
    let (n1, n2, n3) = (4usize, 3usize, 5usize);
    // interleaved noise sessions spread over both shards
    for noise in 0..4u64 {
        let g = cluster
            .router
            .submit_in_session(noise, vec![7 + noise as i32; 3], 2)
            .unwrap();
        assert_eq!(g.len(), 2);
    }
    let g1 = cluster.router.submit_in_session(sid, d1.clone(), n1).unwrap();
    let g2 = cluster.router.submit_in_session(sid, d2.clone(), n2).unwrap();
    // live migration to the other shard between turns 2 and 3
    let home = cluster.router.shard_of(sid).unwrap();
    let target = 1 - home;
    let bytes = cluster.router.migrate(sid, target).unwrap();
    assert!(bytes > 0, "the recurrent engine ships O(1) state bytes");
    assert_eq!(cluster.router.shard_of(sid), Some(target));
    assert!(
        !cluster.router.sessions_on(home).contains(&sid),
        "the source shard must forget the session"
    );
    let g3 = cluster.router.submit_in_session(sid, d3.clone(), n3).unwrap();
    // second turns of the noise sessions, after the migration churn
    for noise in 0..4u64 {
        let g = cluster.router.submit_in_session(noise, vec![2], 2).unwrap();
        assert_eq!(g.len(), 2);
    }
    // uninterrupted baseline
    let r1 = turn(&h_ref, sid, d1, n1);
    let r2 = turn(&h_ref, sid, d2, n2);
    let r3 = turn(&h_ref, sid, d3, n3);
    assert_eq!(g1, r1, "turn 1 diverged");
    assert_eq!(g2, r2, "turn 2 diverged");
    assert_eq!(
        g3, r3,
        "turn 3 after live migration diverged from the uninterrupted run"
    );
    // nothing anywhere fell back to re-prefill: every later turn resumed
    // stored state on the shard it was routed to (affinity), including
    // the migrated one
    let health = cluster.router.health().unwrap();
    assert_eq!(
        health.iter().map(|h| h.session_misses).sum::<u64>(),
        0,
        "a session miss means a turn was routed to a shard without its state"
    );
    assert!(health[target].session_hits >= 1, "turn 3 must resume on the target");
    let hits: u64 = health.iter().map(|h| h.session_hits).sum();
    assert!(hits >= 6, "turn 2, turn 3 and the 4 noise second-turns all resume");
    h_ref.shutdown();
    cluster.shutdown();
}

/// Acceptance: a blob with a foreign format version is rejected at the
/// import handshake with a typed error — and nothing is restored.
#[test]
fn version_mismatched_blob_is_rejected_never_restored() {
    let shard = ShardServer::spawn_native(&shape(), 2, SEED, cfg()).unwrap();
    let mut stream = std::net::TcpStream::connect(shard.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let (engine_tag, fp, wfp) = match wire::read_frame(&mut stream).unwrap() {
        Frame::Hello { engine, shape_fp, weights_fp, .. } => (engine, shape_fp, weights_fp),
        other => panic!("expected Hello, got {other:?}"),
    };
    // a blob claiming a future format version, but otherwise plausible
    let mut st = SessionState::new(&engine_tag, 5);
    st.push_plane("x_re", vec![0.0; 4]);
    let mut bytes = st.to_wire_bytes();
    bytes[4..8].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
    wire::write_frame(
        &mut stream,
        &Frame::Import {
            session: 1,
            shape_fp: fp,
            weights_fp: wfp,
            transcript: vec![1],
            state: Some(bytes),
        },
    )
    .unwrap();
    match wire::read_frame(&mut stream).unwrap() {
        Frame::Error { code, msg } => {
            assert_eq!(code, ErrCode::Mismatch);
            assert!(msg.contains("version"), "error must name the cause: {msg}");
        }
        other => panic!("expected Mismatch, got {other:?}"),
    }
    // the refused import must not have created the session
    wire::write_frame(&mut stream, &Frame::Export { session: 1 }).unwrap();
    assert!(matches!(
        wire::read_frame(&mut stream).unwrap(),
        Frame::Error { code: ErrCode::UnknownSession, .. }
    ));
    shard.shutdown();
}

/// Drain a shard, grow the cluster, rebalance — every conversation keeps
/// producing exactly the tokens its uninterrupted baseline produces.
#[test]
fn drain_and_add_shard_keep_every_conversation_intact() {
    let mut cluster = Cluster::launch_native(3, &shape(), 2, SEED, &cfg()).unwrap();
    let h_ref = reference();
    let sids: Vec<u64> = (100..106).collect();
    for &sid in &sids {
        let d = vec![(sid % 30) as i32 + 1, 2, 3];
        let got = cluster.router.submit_in_session(sid, d.clone(), 3).unwrap();
        let want = turn(&h_ref, sid, d, 3);
        assert_eq!(got, want, "turn 1 of session {sid:#x} diverged");
    }
    // drain shard 0: its sessions migrate away and the shard empties
    cluster.router.drain(0).unwrap();
    assert!(cluster.router.sessions_on(0).is_empty());
    let health = cluster.router.health().unwrap();
    assert_eq!(health[0].sessions_resident, 0, "drained shard still holds sessions");
    // grow the cluster; move sessions whose ring target changed
    let extra = ShardServer::spawn_native(&shape(), 2, SEED, cfg()).unwrap();
    cluster.router.add_shard(extra.addr()).unwrap();
    cluster.router.rebalance().unwrap();
    // after all that churn, every conversation continues bit-identically
    // and never lands on the drained shard
    for &sid in &sids {
        let d = vec![(sid % 7) as i32, 9];
        let got = cluster.router.submit_in_session(sid, d.clone(), 4).unwrap();
        let want = turn(&h_ref, sid, d, 4);
        assert_eq!(got, want, "session {sid:#x} diverged after drain/rebalance");
        assert_ne!(
            cluster.router.shard_of(sid),
            Some(0),
            "drained shard must not serve session turns"
        );
    }
    let health = cluster.router.health().unwrap();
    assert_eq!(
        health.iter().map(|h| h.session_misses).sum::<u64>(),
        0,
        "every post-migration turn must resume shipped state, not re-prefill"
    );
    extra.shutdown();
    h_ref.shutdown();
    cluster.shutdown();
}

/// One wire-level turn through the front door: connect, swallow the
/// greeting, submit, collect the streamed tokens until `Done`.
fn front_turn(addr: std::net::SocketAddr, sid: u64, delta: Vec<i32>, max_new: u32) -> Vec<i32> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    match wire::read_frame(&mut s).unwrap() {
        Frame::Hello { .. } => {}
        other => panic!("expected Hello greeting, got {other:?}"),
    }
    wire::write_frame(
        &mut s,
        &Frame::SubmitInSession {
            session: sid,
            strict: false,
            max_new,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta,
        },
    )
    .unwrap();
    let mut toks = Vec::new();
    loop {
        match wire::read_frame(&mut s).unwrap() {
            Frame::Token { token } => toks.push(token),
            Frame::Done { .. } => return toks,
            other => panic!("expected Token/Done, got {other:?}"),
        }
    }
}

/// Streamed turns keep session affinity: turn 2's per-token relay runs
/// against turn 1's shard (a resume hit there, zero misses anywhere),
/// and in both turns the stream equals the buffered return.
#[test]
fn streamed_turns_keep_affinity_and_match_their_buffered_return() {
    let mut cluster = Cluster::launch_native(2, &shape(), 2, SEED, &cfg()).unwrap();
    let h_ref = reference();
    let sid = 0xAF11;
    let (d1, d2) = (vec![1, 2, 3], vec![9]);
    let mut s1 = Vec::new();
    let g1 = cluster
        .router
        .submit_in_session_streaming(sid, d1.clone(), 4, |t| s1.push(t))
        .unwrap();
    assert_eq!(s1, g1, "turn 1's stream diverged from its return");
    let home = cluster.router.shard_of(sid).unwrap();
    let mut s2 = Vec::new();
    let g2 = cluster
        .router
        .submit_in_session_streaming(sid, d2.clone(), 3, |t| s2.push(t))
        .unwrap();
    assert_eq!(s2, g2, "turn 2's stream diverged from its return");
    assert_eq!(
        cluster.router.shard_of(sid),
        Some(home),
        "turn 2 must stream from turn 1's shard"
    );
    assert_eq!(g1, turn(&h_ref, sid, d1, 4), "turn 1 diverged");
    assert_eq!(g2, turn(&h_ref, sid, d2, 3), "turn 2 diverged");
    let health = cluster.router.health().unwrap();
    assert_eq!(
        health[home].session_hits, 1,
        "turn 2 must resume stored state on the home shard"
    );
    assert_eq!(health.iter().map(|h| h.session_misses).sum::<u64>(), 0);
    h_ref.shutdown();
    cluster.shutdown();
}

/// An admin drain issued while a turn is streaming must defer until the
/// stream completes: the front serializes admin calls behind the same
/// router the relay holds, so the client sees its full uninterrupted
/// token stream, and only then does the session migrate off the shard.
#[test]
fn mid_stream_drain_defers_until_the_stream_completes() {
    let shape = shape();
    let shards: Vec<ShardServer> = (0..2)
        .map(|_| ShardServer::spawn_native(&shape, 2, SEED, cfg()).unwrap())
        .collect();
    let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
    let faults = Arc::new(FaultPlan::new());
    let router = Router::new_with(&addrs, BreakerConfig::default(), Some(faults.clone())).unwrap();
    let front =
        FrontServer::spawn(
            router,
            FrontConfig { max_inflight: 4, probe_interval: None, ..FrontConfig::default() },
        )
        .unwrap();
    let h_ref = reference();
    let sid = 0xD8A1;
    let (d1, d2) = (vec![2, 7, 1], vec![8, 2]);

    // hold the token relay open mid-stream so the drain demonstrably
    // arrives while the streamed turn is still in flight
    faults.add_rule(Rule {
        shard: None,
        point: Point::TokenStream { after: 2 },
        action: FaultAction::Delay(Duration::from_millis(300)),
        times: 1,
    });

    let (tx, rx) = mpsc::channel();
    let addr = front.addr();
    let d1c = d1.clone();
    let client = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        match wire::read_frame(&mut s).unwrap() {
            Frame::Hello { .. } => {}
            other => panic!("expected Hello greeting, got {other:?}"),
        }
        wire::write_frame(
            &mut s,
            &Frame::SubmitInSession {
                session: sid,
                strict: false,
                max_new: 5,
                deadline_ms: 0,
                trace: 0,
                profile: false,
                delta: d1c,
            },
        )
        .unwrap();
        let mut toks = Vec::new();
        loop {
            match wire::read_frame(&mut s).unwrap() {
                Frame::Token { token } => {
                    toks.push(token);
                    let _ = tx.send(());
                }
                Frame::Done { .. } => return toks,
                other => panic!("expected Token/Done, got {other:?}"),
            }
        }
    });

    // first streamed token seen → the turn is in flight; now ask for the
    // drain.  The lock blocks until the relay finishes, so by the time we
    // hold the router the turn must be complete and resident.
    rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let router = front.router();
    let mut r = router.lock().unwrap();
    let home = r
        .shard_of(sid)
        .expect("the streamed turn must have completed before the drain ran");
    let moved = r.drain(home).unwrap();
    assert_eq!(moved, vec![sid], "the drain must migrate the streamed session");
    assert!(r.sessions_on(home).is_empty(), "drained shard still lists the session");
    let new_home = r.shard_of(sid).unwrap();
    assert_ne!(new_home, home, "the session must move off the drained shard");
    drop(r);

    // the stream was never cut: the client saw the full turn
    let g1 = client.join().unwrap();
    assert_eq!(g1, turn(&h_ref, sid, d1, 5), "the streamed-through-drain turn diverged");
    assert_eq!(faults.rules_pending(), 0, "the staged mid-stream delay never fired");

    // and the conversation continues on the new home, bit-identically
    let g2 = front_turn(addr, sid, d2.clone(), 4);
    assert_eq!(g2, turn(&h_ref, sid, d2, 4), "post-drain turn diverged");

    h_ref.shutdown();
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}
