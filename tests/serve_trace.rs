//! Loopback integration test of end-to-end distributed tracing: a
//! 2-shard cluster behind a [`FrontServer`], driven over real wire
//! sockets by clients that stamp their own trace ids.
//!
//! The acceptance invariants:
//!
//! * a traced turn's `Spans` report joins front → router → shard →
//!   coordinator → engine into **one tree** whose hop durations nest
//!   (every inner hop fits inside its parent) and account for the
//!   front-observed end-to-end latency within a small assembly slack;
//! * skipped stages are *absent* end-to-end: the first turn's
//!   coordinator hop carries `prefill` and no `resume`, the second
//!   turn's carries `resume` and no `prefill`;
//! * a session whose home shard is killed mid-conversation still
//!   answers, and the surviving turn's span tree is annotated
//!   `resurrected`; a one-shot that lands on the dead shard first is
//!   annotated `retry:1`;
//! * `GET /trace/<id>` serves the same joined tree over HTTP, and the
//!   sampled engine profile feeds the `lh_engine_*` histograms visible
//!   in a `/metrics` scrape.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use laughing_hyena::config::ServeConfig;
use laughing_hyena::engine::LmShape;
use laughing_hyena::obs::HopReport;
use laughing_hyena::serve::wire;
use laughing_hyena::serve::{
    BreakerConfig, FaultPlan, Frame, FrontConfig, FrontServer, Router, ShardServer,
};

/// Shared seed: every shard carries identical weights, the precondition
/// for resurrecting a killed session anywhere in the cluster.
const SEED: u64 = 11;

/// Slack allowed between a parent hop's total and the sum of the work it
/// directly measured: record assembly, frame writes and scheduler noise
/// live in this gap, never generation work.
const SLACK_US: u64 = 50_000;

fn cfg() -> ServeConfig {
    ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
}

fn shape() -> LmShape {
    LmShape::bench("nano").unwrap()
}

/// N native shards behind a front server with a fault plan threaded in
/// and the background prober disabled.
fn launch(n: usize) -> (Vec<ShardServer>, FrontServer, Arc<FaultPlan>) {
    let shape = shape();
    let shards: Vec<ShardServer> =
        (0..n).map(|_| ShardServer::spawn_native(&shape, 2, SEED, cfg()).unwrap()).collect();
    let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
    let faults = Arc::new(FaultPlan::new());
    let router = Router::new_with(&addrs, BreakerConfig::default(), Some(faults.clone())).unwrap();
    let front =
        FrontServer::spawn(
            router,
            FrontConfig { max_inflight: 4, probe_interval: None, ..FrontConfig::default() },
        )
        .unwrap();
    (shards, front, faults)
}

/// One blocking HTTP/1.1 exchange against the sibling listener.
fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    s.write_all(format!("GET {path} HTTP/1.1\r\nhost: t\r\n\r\n").as_bytes()).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("unparseable status line in {text:?}"));
    let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

/// A histogram `_count` / counter value from a Prometheus text body.
fn metric_value(body: &str, name: &str) -> u64 {
    body.lines()
        .find_map(|l| {
            l.strip_prefix(name)
                .and_then(|r| r.strip_prefix(' '))
                .and_then(|v| v.trim().parse::<f64>().ok())
        })
        .unwrap_or_else(|| panic!("metric {name} not found in scrape")) as u64
}

/// One traced wire turn: connect, swallow the greeting, submit, collect
/// the stream plus the `Spans` report, return (tokens, hops, Done trace).
fn traced_turn(addr: SocketAddr, submit: &Frame) -> (Vec<i32>, Vec<HopReport>, u64) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    match wire::read_frame(&mut s).unwrap() {
        Frame::Hello { .. } => {}
        other => panic!("expected Hello greeting, got {other:?}"),
    }
    wire::write_frame(&mut s, submit).unwrap();
    let mut toks = Vec::new();
    let mut hops = Vec::new();
    loop {
        match wire::read_frame(&mut s).unwrap() {
            Frame::Token { token } => toks.push(token),
            Frame::Spans { hops: h, .. } => hops = h,
            Frame::Done { trace, .. } => return (toks, hops, trace),
            other => panic!("expected Token/Spans/Done, got {other:?}"),
        }
    }
}

fn in_session(sid: u64, trace: u64, delta: Vec<i32>, max_new: u32) -> Frame {
    Frame::SubmitInSession {
        session: sid,
        strict: false,
        max_new,
        deadline_ms: 0,
        trace,
        profile: true,
        delta,
    }
}

/// The hop by name, or panic with the tree that was actually reported.
fn hop<'a>(hops: &'a [HopReport], name: &str) -> &'a HopReport {
    hops.iter()
        .find(|h| h.hop == name)
        .unwrap_or_else(|| panic!("no {name} hop in {:?}", hops.iter().map(|h| &h.hop).collect::<Vec<_>>()))
}

/// Tentpole: a traced, profiled turn's span report joins every layer
/// into one tree with nesting durations that account for the front's
/// end-to-end latency, skipped stages are absent (prefill vs resume),
/// and `GET /trace/<id>` serves the same tree over HTTP with the engine
/// profile visible in `/metrics`.
#[test]
fn traced_turns_join_one_tree_that_accounts_for_e2e_latency() {
    let (shards, front, _faults) = launch(2);
    let sid = 0x51D;
    let (t1, t2) = (0xAAA1u64, 0xAAA2u64);

    let wall = Instant::now();
    let (toks, hops, done_trace) = traced_turn(front.addr(), &in_session(sid, t1, vec![3, 1, 4], 4));
    let client_e2e_us = wall.elapsed().as_micros() as u64;
    assert_eq!(toks.len(), 4);
    assert_eq!(done_trace, t1, "Done must echo the client's trace id");

    // one tree, every layer present, in traversal order
    let names: Vec<&str> = hops.iter().map(|h| h.hop.as_str()).collect();
    assert_eq!(
        names,
        ["front", "router", "shard", "coordinator", "engine"],
        "hops must join front-first in traversal order"
    );

    // durations nest: every hop fits inside the one that carried it,
    // and the outermost fits inside what the client itself observed
    let (front_hop, router_hop) = (hop(&hops, "front"), hop(&hops, "router"));
    let (shard_hop, coord_hop) = (hop(&hops, "shard"), hop(&hops, "coordinator"));
    let engine_hop = hop(&hops, "engine");
    assert!(front_hop.total_us <= client_e2e_us, "front e2e exceeds the client's own clock");
    assert!(router_hop.total_us <= front_hop.total_us);
    assert!(shard_hop.total_us <= router_hop.total_us);
    assert!(coord_hop.total_us <= shard_hop.total_us);
    assert!(engine_hop.total_us <= coord_hop.total_us);

    // the front's own spans account for its total within assembly slack
    let queue = front_hop.span_named("queue").expect("front queue span");
    let relay = front_hop.span_named("relay").expect("front relay span");
    assert_eq!(queue.start_us, 0);
    assert_eq!(relay.start_us, queue.dur_us, "relay starts where queue ends");
    let accounted = queue.dur_us + relay.dur_us;
    assert!(accounted <= front_hop.total_us, "spans cannot exceed their hop");
    assert!(
        front_hop.total_us - accounted <= SLACK_US,
        "unaccounted front time {}us exceeds slack",
        front_hop.total_us - accounted
    );
    // the relay span is where the router's custody lives
    assert!(router_hop.total_us <= relay.dur_us);

    // the shard splits its custody at the first token
    let tft = shard_hop.span_named("to_first_token").expect("shard to_first_token span");
    let stream = shard_hop.span_named("stream").expect("shard stream span");
    assert_eq!(stream.start_us, tft.dur_us);
    assert!(tft.dur_us + stream.dur_us <= shard_hop.total_us + SLACK_US);

    // first turn of a session: prefill happened, resume is *absent*
    assert!(coord_hop.span_named("queue").is_some());
    assert!(coord_hop.span_named("decode").is_some());
    assert!(coord_hop.span_named("prefill").is_some(), "turn 1 must prefill");
    assert!(coord_hop.span_named("resume").is_none(), "no stored state to resume on turn 1");

    // the profiled engine hop carries every hot-path stage (start 0:
    // stages interleave per token, durations are per-request aggregates)
    for stage in ["short_conv", "modal_sweep", "qkv", "out_proj", "mlp", "lm_head"] {
        let s = engine_hop
            .span_named(stage)
            .unwrap_or_else(|| panic!("missing engine stage {stage}"));
        assert_eq!(s.start_us, 0, "engine stages carry no offsets");
    }

    // turn 2 resumes stored state: resume present, prefill absent
    let (_, hops2, done2) = traced_turn(front.addr(), &in_session(sid, t2, vec![1, 5], 3));
    assert_eq!(done2, t2);
    let coord2 = hop(&hops2, "coordinator");
    assert!(coord2.span_named("resume").is_some(), "turn 2 must resume stored state");
    assert!(coord2.span_named("prefill").is_none(), "a resumed turn never prefills");

    // the same trees over HTTP: /trace/<id> joins, /traces?session filters
    let (status, body) = http_get(front.http_addr(), &format!("/trace/{t1}"));
    assert_eq!(status, 200, "{body}");
    assert!(body.contains(&format!("\"id\":{t1}")), "{body}");
    for name in ["front", "router", "shard", "coordinator", "engine"] {
        assert!(body.contains(&format!("\"hop\":\"{name}\"")), "{name} missing from {body}");
    }
    assert!(body.contains("\"name\":\"modal_sweep\""), "{body}");
    let (status, filtered) = http_get(front.http_addr(), &format!("/traces?session={sid}"));
    assert_eq!(status, 200);
    assert!(filtered.contains(&format!("\"id\":{t1}")), "{filtered}");
    assert!(filtered.contains(&format!("\"id\":{t2}")), "{filtered}");
    let (status, missing) = http_get(front.http_addr(), "/trace/999999999");
    assert_eq!(status, 404, "an unseen id must be a clean 404: {missing}");

    // the profiled turns fed the engine-stage histograms
    let (status, scrape) = http_get(front.http_addr(), "/metrics");
    assert_eq!(status, 200);
    assert!(metric_value(&scrape, "lh_engine_profiled_total") >= 2, "{scrape}");
    assert!(metric_value(&scrape, "lh_engine_modal_sweep_seconds_count") >= 2, "{scrape}");
    assert!(metric_value(&scrape, "lh_engine_lm_head_seconds_count") >= 2, "{scrape}");

    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}

/// Satellite: kill a traced session's home shard mid-conversation.  The
/// next turn still answers — and its span tree says *how*: the router
/// hop is annotated `resurrected`, and the joined tree (wire and HTTP
/// alike) still carries every hop from the surviving attempt.
#[test]
fn killed_session_turn_is_annotated_resurrected_in_its_span_tree() {
    let (shards, front, faults) = launch(2);
    let sid = 0xDEAD_5EED;
    let (t1, t2) = (0xBBB1u64, 0xBBB2u64);

    let (toks1, hops1, _) = traced_turn(front.addr(), &in_session(sid, t1, vec![3, 1, 4], 4));
    assert_eq!(toks1.len(), 4);
    assert!(
        hops1.iter().all(|h| h.notes.is_empty()),
        "an unremarkable turn carries no annotations: {hops1:?}"
    );

    // the home shard "crashes": every connect to it is refused from now on
    let home = front.router().lock().unwrap().shard_of(sid).unwrap();
    faults.kill(shards[home].addr());

    let (toks2, hops2, done2) = traced_turn(front.addr(), &in_session(sid, t2, vec![1, 5, 9], 3));
    assert_eq!(toks2.len(), 3, "the killed session's turn must still answer");
    assert_eq!(done2, t2);
    let router_hop = hop(&hops2, "router");
    assert!(
        router_hop.notes.iter().any(|n| n == "resurrected"),
        "the surviving turn must be annotated resurrected: {:?}",
        router_hop.notes
    );
    // the resurrected attempt's downstream reports still joined the tree
    for name in ["shard", "coordinator", "engine"] {
        assert!(hops2.iter().any(|h| h.hop == name), "{name} missing after resurrection");
    }
    // and the session now answers from the survivor
    assert_ne!(front.router().lock().unwrap().shard_of(sid), Some(home));

    // the annotation is queryable after the fact
    let (status, body) = http_get(front.http_addr(), &format!("/trace/{t2}"));
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"resurrected\""), "{body}");

    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}

/// Satellite: a one-shot whose first routing choice is the dead shard is
/// annotated `retry:1` — the trace says the latency went to failover,
/// not generation.  Round-robin alternates the starting shard, so of two
/// back-to-back one-shots exactly the one that led with the corpse
/// carries the note.  Also pins the sampling contract: tracing forces
/// profiling, while an untraced unprofiled request gets no engine hop
/// and no Spans frame.
#[test]
fn one_shot_failover_is_annotated_retry_in_its_span_tree() {
    let (shards, front, faults) = launch(2);
    faults.kill(shards[0].addr());
    let (ta, tb) = (0xCCC1u64, 0xCCC2u64);
    let submit = |trace| Frame::Submit {
        max_new: 3,
        deadline_ms: 0,
        trace,
        profile: false,
        prompt: vec![2, 7, 1],
    };

    let (toks_a, hops_a, _) = traced_turn(front.addr(), &submit(ta));
    let (toks_b, hops_b, _) = traced_turn(front.addr(), &submit(tb));
    assert_eq!(toks_a.len(), 3, "failover must still answer");
    assert_eq!(toks_b.len(), 3);

    let retried: Vec<bool> = [&hops_a, &hops_b]
        .iter()
        .map(|hops| hop(hops, "router").notes.iter().any(|n| n == "retry:1"))
        .collect();
    assert_eq!(
        retried.iter().filter(|&&r| r).count(),
        1,
        "exactly one of two round-robin one-shots leads with the dead shard: {hops_a:?} / {hops_b:?}"
    );

    // tracing forces profiling (the whole point of tracing a slow
    // request is seeing where the engine spent it), so even with
    // profile:false on the frame the retried tree carries every hop
    let annotated = if retried[0] { &hops_a } else { &hops_b };
    for name in ["front", "router", "shard", "coordinator", "engine"] {
        assert!(annotated.iter().any(|h| h.hop == name), "{name} missing");
    }

    // an UNtraced, unprofiled request never pays for engine stage
    // timing — its ring record (looked up via the minted id `Done`
    // echoes) has no engine hop, and no Spans frame reached the wire
    let plain = Frame::Submit {
        max_new: 3,
        deadline_ms: 0,
        trace: 0,
        profile: false,
        prompt: vec![2, 7, 1],
    };
    let (toks_p, hops_p, minted) = traced_turn(front.addr(), &plain);
    assert_eq!(toks_p.len(), 3);
    assert!(hops_p.is_empty(), "untraced clients must not receive Spans frames");
    assert_ne!(minted, 0, "Done must still echo a minted trace id");
    let (status, body) = http_get(front.http_addr(), &format!("/trace/{minted}"));
    assert_eq!(status, 200, "{body}");
    assert!(
        !body.contains("\"hop\":\"engine\""),
        "an unprofiled request must not pay for engine stage timing: {body}"
    );
    assert!(body.contains("\"hop\":\"coordinator\""), "{body}");

    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}
