//! Crash-durability acceptance harness: the write-ahead turn journal
//! under process death.
//!
//! Three crash shapes, each checked against an uninterrupted reference
//! coordinator carrying the same seed (so every engine in play is
//! bit-identical):
//!
//! * **Router death mid-load** — the router instance (and its in-memory
//!   transcript mirror) is dropped while concurrent sessions are
//!   mid-conversation, the shards keep running, and a fresh router is
//!   rebuilt solely from journal replay.  Every acked turn must survive
//!   bit-identically, a retry of the last acked turn must be served from
//!   the replay-dedup window *without touching any shard* (exactly-once),
//!   and the conversations must continue as if nothing happened.
//! * **Full-cluster cold restart** — front, router and every shard shut
//!   down; the whole cluster relaunches from `--journal-dir` with empty
//!   shards.  The census must reconcile (each journaled session resumes
//!   on exactly one shard via transcript re-prefill) with zero lost
//!   acked turns.
//! * **Torn tail / flipped bit** — a partial record appended by a crash
//!   mid-write is truncated at open (and counted); a checksum-corrupted
//!   record in the sealed region is a *typed* [`JournalError::Corrupt`]
//!   refusal — at the journal layer and surfaced through the serve-layer
//!   launcher — never a panic, never silently served.

use std::collections::HashMap;
use std::fs;
use std::io::Write as _;
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::thread;
use std::time::{Duration, Instant};

use laughing_hyena::config::{FsyncPolicy, ServeConfig};
use laughing_hyena::coordinator::server::spawn;
use laughing_hyena::coordinator::{CoordinatorHandle, SlotEngine};
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::LmShape;
use laughing_hyena::obs::registry::{MetricValue, Snapshot};
use laughing_hyena::serve::wire;
use laughing_hyena::serve::{
    BreakerConfig, Cluster, ErrCode, Frame, FrontConfig, FrontServer, RouteError, Router,
    ShardServer,
};
use laughing_hyena::session::{Journal, JournalConfig, JournalError};

/// Every shard, every restarted shard, and the reference coordinator
/// share this seed — identical weights are what make "resumes
/// bit-identically" a meaningful claim.
const SEED: u64 = 11;

/// Tokens requested per turn.
const MAX_NEW: usize = 3;

/// Deadline budget: generous, nothing in this harness is meant to shed.
const PATIENT_MS: u32 = 120_000;

/// A fresh scratch directory under the system temp dir, cleared of any
/// residue from a previous run of the same test.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lh_crash_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn cfg() -> ServeConfig {
    ServeConfig { max_batch: 4, linger_ms: 1, ..ServeConfig::default() }
}

/// The journaled variant: every acked turn is durable before the ack
/// (per-record fsync keeps the crash windows exact for the test).
fn journaled_cfg(jdir: &Path) -> ServeConfig {
    ServeConfig {
        journal_dir: Some(jdir.to_string_lossy().into_owned()),
        journal_fsync: FsyncPolicy::PerRecord,
        ..cfg()
    }
}

fn jcfg(jdir: &Path) -> JournalConfig {
    let mut c = JournalConfig::new(jdir);
    c.fsync = FsyncPolicy::PerRecord;
    c
}

fn shape() -> LmShape {
    LmShape::bench("nano").unwrap()
}

/// The uninterrupted baseline: one coordinator, never crashed.
fn reference() -> CoordinatorHandle {
    let shape = shape();
    spawn(move || Box::new(RecurrentEngine::new(&shape, 4, SEED)) as Box<dyn SlotEngine>, cfg())
}

fn ref_turn(h: &CoordinatorHandle, sid: u64, delta: Vec<i32>, n: usize) -> Vec<i32> {
    h.submit_in_session(sid, delta, n)
        .unwrap()
        .recv_timeout(Duration::from_secs(120))
        .unwrap()
        .tokens
}

/// An `n`-shard journaled cluster + front door.
fn launch(n: usize, serve_cfg: &ServeConfig) -> (Vec<ShardServer>, FrontServer) {
    let cluster = Cluster::launch_native_with(
        n,
        &shape(),
        4,
        SEED,
        serve_cfg,
        BreakerConfig { cooldown: Duration::ZERO, ..BreakerConfig::default() },
        None,
    )
    .unwrap();
    let (shards, router) = cluster.into_parts();
    let front = FrontServer::spawn(
        router,
        FrontConfig { max_inflight: 32, probe_interval: None, ..FrontConfig::default() },
    )
    .unwrap();
    (shards, front)
}

/// One wire-level turn through the front door; a non-typed failure is a
/// harness bug, not chaos.
fn wire_turn(addr: SocketAddr, sid: u64, delta: &[i32]) -> Result<Vec<i32>, ErrCode> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    match wire::read_frame(&mut s).unwrap() {
        Frame::Hello { .. } => {}
        other => panic!("expected Hello greeting, got {other:?}"),
    }
    wire::write_frame(
        &mut s,
        &Frame::SubmitInSession {
            session: sid,
            strict: false,
            max_new: MAX_NEW as u32,
            deadline_ms: PATIENT_MS,
            trace: 0,
            profile: false,
            delta: delta.to_vec(),
        },
    )
    .unwrap();
    let mut toks = Vec::new();
    loop {
        match wire::read_frame(&mut s).unwrap() {
            Frame::Token { token } => toks.push(token),
            Frame::Done { .. } => return Ok(toks),
            Frame::Error { code, .. } => return Err(code),
            other => panic!("expected Token/Done/Error, got {other:?}"),
        }
    }
}

fn counter(snap: &Snapshot, name: &str) -> u64 {
    match snap.entries.get(name) {
        Some(MetricValue::Counter(v)) | Some(MetricValue::Gauge(v)) => *v,
        _ => 0,
    }
}

/// Poll until `pred` holds or the timeout elapses (then panic with `what`).
fn wait_until(what: &str, timeout: Duration, mut pred: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !pred() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(5));
    }
}

// deterministic per-session turn deltas (the reference replays the same)
fn turn1(sid: u64) -> Vec<i32> {
    vec![2 + (sid % 9) as i32; 4]
}
fn turn2(sid: u64) -> Vec<i32> {
    vec![1 + (sid % 6) as i32, 8]
}
fn turn3(sid: u64) -> Vec<i32> {
    vec![5, 1 + (sid % 4) as i32]
}

/// The tentpole: 24 concurrent sessions converse through the front door,
/// then the router "process" dies mid-load — the instance is dropped,
/// its in-memory mirror and dedup state gone, while every shard keeps
/// running.  A fresh router is rebuilt *solely* from journal replay and
/// must (a) hold every acked turn byte-for-byte in its rebuilt mirror,
/// (b) serve a client retry of the last acked turn from the replay-dedup
/// window bit-identically *without contacting any shard* — the
/// crash-between-append-and-ack window closed exactly once — and
/// (c) continue every conversation bit-identically against an
/// uninterrupted reference coordinator.
#[test]
fn router_death_mid_load_resumes_every_acked_turn_exactly_once() {
    let jdir = tmp("router_death");
    let serve_cfg = journaled_cfg(&jdir);
    let (shards, front) = launch(2, &serve_cfg);
    let addr = front.addr();
    let n_sessions = 24u64;

    // phase 1, concurrent: every session opens; even sessions get two
    // turns deep, odd sessions one — the crash lands mid-conversation at
    // mixed depths
    let workers: Vec<_> = (1..=n_sessions)
        .map(|sid| {
            thread::spawn(move || {
                let mut log: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
                let d1 = turn1(sid);
                let g1 = wire_turn(addr, sid, &d1).expect("turn 1 refused");
                assert_eq!(g1.len(), MAX_NEW);
                log.push((d1, g1));
                if sid % 2 == 0 {
                    let d2 = turn2(sid);
                    let g2 = wire_turn(addr, sid, &d2).expect("turn 2 refused");
                    assert_eq!(g2.len(), MAX_NEW);
                    log.push((d2, g2));
                }
                (sid, log)
            })
        })
        .collect();
    let mut logs: HashMap<u64, Vec<(Vec<i32>, Vec<i32>)>> = HashMap::new();
    for w in workers {
        let (sid, log) = w.join().expect("load worker panicked");
        logs.insert(sid, log);
    }
    let phase1_turns: u64 = logs.values().map(|l| l.len() as u64).sum();

    // the crash: drop the front and with it the router — mirror, resident
    // pins and dedup state all gone.  The shards never notice.
    front.shutdown();

    // the restart: a fresh router over the same shard addresses, state
    // rebuilt solely by replaying the journal
    let addrs: Vec<SocketAddr> = shards.iter().map(|s| s.addr()).collect();
    let mut router = Router::new(&addrs).unwrap();
    let (journal, replay) = Journal::open(jcfg(&jdir)).unwrap();
    assert!(
        journal.stats().replayed >= phase1_turns,
        "replay applied {} records for {phase1_turns} acked turns",
        journal.stats().replayed
    );
    assert_eq!(journal.stats().truncated_tails, 0, "a clean drop must leave no torn tail");

    // (a) the rebuilt mirror holds every acked turn byte-for-byte
    for (sid, log) in &logs {
        let expect: Vec<i32> =
            log.iter().flat_map(|(d, g)| d.iter().chain(g.iter()).copied()).collect();
        assert_eq!(
            replay.sessions.get(sid),
            Some(&expect),
            "session {sid:#x} transcript lost or mangled across the crash"
        );
        let (last_delta, last_gen) = log.last().unwrap();
        assert_eq!(
            replay.last_turn.get(sid),
            Some(&(last_delta.clone(), last_gen.clone())),
            "session {sid:#x} dedup window not rebuilt from replay"
        );
    }
    router.attach_journal(journal, replay);

    // (b) exactly-once: a client that never saw the ack retries its last
    // turn verbatim — the restarted router must answer bit-identically
    // from the dedup window without contacting any shard
    let retry_sid = 2u64;
    let (retry_delta, retry_gen) = logs[&retry_sid].last().unwrap().clone();
    let before: u64 = router.health().unwrap().iter().map(|h| h.requests_done).sum();
    let again = router.submit_in_session(retry_sid, retry_delta, MAX_NEW).unwrap();
    assert_eq!(again, retry_gen, "the deduped retry must replay the acked tokens verbatim");
    let after: u64 = router.health().unwrap().iter().map(|h| h.requests_done).sum();
    assert_eq!(after, before, "a deduped retry must not reach any shard");
    assert_eq!(router.journal_stats().unwrap().deduped, 1);

    // (c) phase 2, concurrent again through a fresh front door: every
    // conversation continues where it left off
    let front = FrontServer::spawn(
        router,
        FrontConfig { max_inflight: 32, probe_interval: None, ..FrontConfig::default() },
    )
    .unwrap();
    let addr = front.addr();
    let workers: Vec<_> = (1..=n_sessions)
        .map(|sid| {
            thread::spawn(move || {
                let d = if sid % 2 == 0 { turn3(sid) } else { turn2(sid) };
                let g = wire_turn(addr, sid, &d).expect("post-restart turn refused");
                assert_eq!(g.len(), MAX_NEW);
                (sid, d, g)
            })
        })
        .collect();
    for w in workers {
        let (sid, d, g) = w.join().expect("post-restart worker panicked");
        logs.get_mut(&sid).unwrap().push((d, g));
    }

    // bit-identical end to end: replay every session's full turn sequence
    // on the uninterrupted reference
    let h_ref = reference();
    let mut sids: Vec<u64> = logs.keys().copied().collect();
    sids.sort_unstable();
    for sid in sids {
        for (turn_no, (delta, gen)) in logs[&sid].iter().enumerate() {
            let expect = ref_turn(&h_ref, sid, delta.clone(), MAX_NEW);
            assert_eq!(
                gen, &expect,
                "session {sid:#x} turn {turn_no} diverged from the uninterrupted reference \
                 across the router crash"
            );
        }
    }

    // the restarted journal's own ledger: one append per post-restart
    // turn, the one dedup, zero append failures
    let snap = front.router().lock().unwrap().cluster_metrics();
    assert_eq!(counter(&snap, "lh_journal_appended_total"), n_sessions);
    assert_eq!(counter(&snap, "lh_journal_deduped_total"), 1);
    assert_eq!(counter(&snap, "lh_journal_append_errors_total"), 0);
    assert!(counter(&snap, "lh_journal_replayed_total") >= phase1_turns);

    h_ref.shutdown();
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
    let _ = fs::remove_dir_all(&jdir);
}

/// Full-cluster cold restart: front, router and every shard go down;
/// the cluster relaunches from `--journal-dir` with completely empty
/// shards.  Every journaled session must resume through transcript
/// re-prefill bit-identically (zero lost acked turns), and the census
/// must reconcile: each session live on exactly one shard, nothing in
/// flight, no export residue.
#[test]
fn full_cluster_cold_restart_reconciles_census_with_zero_lost_turns() {
    let jdir = tmp("cold_restart");
    let serve_cfg = journaled_cfg(&jdir);
    let (shards, front) = launch(2, &serve_cfg);
    let addr = front.addr();
    let n_sessions = 12u64;

    let mut logs: HashMap<u64, Vec<(Vec<i32>, Vec<i32>)>> = HashMap::new();
    for sid in 1..=n_sessions {
        let d1 = turn1(sid);
        let g1 = wire_turn(addr, sid, &d1).unwrap();
        let d2 = turn2(sid);
        let g2 = wire_turn(addr, sid, &d2).unwrap();
        logs.insert(sid, vec![(d1, g1), (d2, g2)]);
    }

    // everything dies: front + router (mirror gone) and every shard
    // (session state, transcripts, engine slots — all gone)
    front.shutdown();
    for s in shards {
        s.shutdown();
    }

    // cold restart: same seed, same journal dir, brand-new empty shards
    let (shards, front) = launch(2, &serve_cfg);
    let addr = front.addr();
    let snap = front.router().lock().unwrap().cluster_metrics();
    assert!(
        counter(&snap, "lh_journal_replayed_total") >= 2 * n_sessions,
        "cold start must rebuild the mirror from journal replay"
    );

    // every session resumes: the shard holds nothing, so the turn rides
    // the strict → UnknownSession → transcript-re-prefill path, and the
    // result must match a reference that never restarted anything
    let h_ref = reference();
    for sid in 1..=n_sessions {
        for (delta, gen) in &logs[&sid] {
            let expect = ref_turn(&h_ref, sid, delta.clone(), MAX_NEW);
            assert_eq!(gen, &expect, "session {sid:#x} pre-crash turn diverged");
        }
        let d3 = turn3(sid);
        let g3 = wire_turn(addr, sid, &d3).expect("post-cold-restart turn refused");
        assert_eq!(
            g3,
            ref_turn(&h_ref, sid, d3, MAX_NEW),
            "session {sid:#x} lost acked context across the cold restart"
        );
    }
    let snap = front.router().lock().unwrap().cluster_metrics();
    assert!(
        counter(&snap, "lh_resurrections_total") >= n_sessions,
        "cold-restart resumes must go through the transcript-mirror rebuild"
    );

    // census reconciliation: exactly one live copy per session, nothing
    // in flight anywhere, no export stash residue
    for sid in 1..=n_sessions {
        let live: usize =
            shards.iter().map(|s| s.handle.session_known(sid).unwrap() as usize).sum();
        assert_eq!(live, 1, "session {sid:#x} must be live on exactly one shard");
    }
    for (i, shard) in shards.iter().enumerate() {
        wait_until("in-flight turns to settle", Duration::from_secs(30), || {
            shard.handle.session_census().unwrap().in_flight == 0
        });
        assert_eq!(shard.pending_exports(), 0, "shard {i} export stash holds residue");
    }

    h_ref.shutdown();
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
    let _ = fs::remove_dir_all(&jdir);
}

/// Crash-mid-write and bit-rot at the serve layer: a torn (partial)
/// record appended to the live segment is truncated at open and counted,
/// with every acked turn before it intact; a flipped bit inside the
/// sealed region is refused as a typed [`JournalError::Corrupt`] — both
/// directly at [`Journal::open`] and surfaced through
/// [`Cluster::launch_native`] as a typed [`RouteError`] — never a panic.
#[test]
fn torn_tail_truncates_and_sealed_corruption_is_a_typed_refusal() {
    let jdir = tmp("torn_tail");
    let serve_cfg = journaled_cfg(&jdir);
    let mut cluster = Cluster::launch_native(1, &shape(), 4, SEED, &serve_cfg).unwrap();
    let mut expect: HashMap<u64, Vec<i32>> = HashMap::new();
    for sid in 1..=3u64 {
        for delta in [turn1(sid), turn2(sid)] {
            let gen = cluster.router.submit_in_session(sid, delta.clone(), MAX_NEW).unwrap();
            let t = expect.entry(sid).or_default();
            t.extend_from_slice(&delta);
            t.extend_from_slice(&gen);
        }
    }
    cluster.shutdown();

    // the crash-mid-write: a record whose length prefix promises more
    // bytes than the file holds, exactly what a power cut mid-append
    // leaves behind
    let wal0 = jdir.join("wal0.log");
    let clean_len = fs::metadata(&wal0).unwrap().len();
    let mut f = fs::OpenOptions::new().append(true).open(&wal0).unwrap();
    f.write_all(&[200, 0, 0, 0, 1, 7, 7]).unwrap();
    f.sync_all().unwrap();
    drop(f);

    let (journal, replay) = Journal::open(jcfg(&jdir)).unwrap();
    assert_eq!(journal.stats().truncated_tails, 1, "the torn tail must be counted");
    assert_eq!(
        fs::metadata(&wal0).unwrap().len(),
        clean_len,
        "truncation must restore the exact pre-crash length"
    );
    for (sid, transcript) in &expect {
        assert_eq!(
            replay.sessions.get(sid),
            Some(transcript),
            "session {sid:#x} acked turns lost to the torn-tail truncation"
        );
    }
    drop(journal);

    // bit-rot in the sealed region: flip one payload byte of the first
    // record — the checksum catches it, and because valid records follow
    // it this is corruption, not a torn tail
    let mut data = fs::read(&wal0).unwrap();
    data[5] ^= 0x01;
    fs::write(&wal0, &data).unwrap();
    match Journal::open(jcfg(&jdir)) {
        Err(JournalError::Corrupt { segment, offset, .. }) => {
            assert_eq!(segment, "wal0.log");
            assert_eq!(offset, 0, "the corrupt record starts at the head of the segment");
        }
        other => panic!("expected a typed Corrupt refusal, got {:?}", other.map(|_| ())),
    }
    // and the serve layer refuses the same way: a typed launch error,
    // not a panic and not a silently-forgetful cluster
    match Cluster::launch_native(1, &shape(), 4, SEED, &serve_cfg) {
        Err(RouteError::Protocol(msg)) => {
            assert!(msg.contains("corrupt"), "refusal must say why: {msg}");
        }
        Err(other) => panic!("expected a Protocol refusal, got {other:?}"),
        Ok(_) => panic!("a corrupt journal must refuse to serve"),
    }
    let _ = fs::remove_dir_all(&jdir);
}
